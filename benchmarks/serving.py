"""Serving-engine benchmark: bucketed/batched serving vs per-request
execution (exec.serving — ISSUE 4).

The per-request baseline is what a naive front-end would do with the
executor: a batch-1 plan and one compiled ``execute_cnn`` call per
arriving image, blocking for each result.  The serving engine amortizes
the per-call overhead by coalescing traffic into power-of-two batch
buckets, each pre-traced at ``warmup()``, and is thread-safe — so the
sustained number is measured the way a real front-end would run it:
a couple of request worker threads streaming max-bucket batches
(pipelined dispatch), exactly the concurrency the executor-cache locks
of this PR make safe.  Measured contrasts:

  * **bucketed_ips** — sustained warm images/sec, 2 worker threads
    streaming max-bucket batches through ``ServingEngine.infer`` (a
    mixed-size stream follows to exercise padding, whose overhead
    fraction rides along in the stats);
  * **per_request_ips** — warm single-image blocking ``execute_cnn``;
  * **zero retraces** after warmup across all bucket reuse (trace_count
    pinned — a regression to per-shape tracing trips the gate);
  * **data-parallel bit-identity** — with >= 2 devices (CI forces 4
    virtual CPU devices via XLA_FLAGS), the NamedSharding data-parallel
    path must return logits bitwise equal to single-device (noise off).

Networks are zoo graphs served at 16x16 (the engine's ``in_hw`` knob):
small request tensors are the regime the serving layer exists for — the
Mixed-Sized Tensors observation (PAPERS.md, arXiv:2207.05278) — and at
32x32 the host-simulation compute swamps the per-request overhead the
engine amortizes.  Acceptance (full run): bucketed serving sustains
>= 5x per-request throughput on at least two zoo networks.  ``--smoke``
runs reduced reps with a looser floor for CI and exits nonzero on any
contract breach.

NOTE on units: images/sec is HOST SIMULATION throughput (Pallas kernel
in interpret mode on CPU) — it validates the serving software path, not
the photonic perf model's FPS.
"""
from __future__ import annotations

import argparse
import os
import sys
import threading
import time
from typing import List, Optional, Sequence, Tuple

import jax

from benchmarks.common import Row
from repro.core import perf_model as pm
from repro.core.types import Backend, Dataflow, PhotonicConfig
from repro.exec import (PlanCache, ServingEngine, execute_cnn,
                        save_summary, serving_summary, trace_count)
from repro.models import lowering as lw
from repro.models.zoo_cnn import ZOO

EXP_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "serving")
# Floor-eligible networks (acceptance: >= 5x on at least two of them in
# the full run; smoke streams the first two with a looser per-network
# floor) + an extra coverage cell.  The >= 5x floor applies to the
# plain single-device environment — forcing virtual host devices
# (XLA_FLAGS) splits the host cores and dampens the concurrent-stream
# gain, which is why the floor run and the dp-evidence run are separate
# rows (artifacts are keyed by device count).
NETWORKS = ("mobilenet_mini", "small_cnn", "shufflenet_mini")
SMOKE_NETWORKS = NETWORKS[:2]
FULL_EXTRA_NETWORKS = ("googlenet_mini",)
IN_HW = 16
MAX_BATCH = 16
STREAM_THREADS = 2
FULL_MIN_SPEEDUP = 5.0
SMOKE_MIN_SPEEDUP = 2.0


def _stream_ips(engine: ServingEngine, batches: List, threads: int) -> float:
    """Sustained warm throughput: ``threads`` workers each streaming the
    given batches with pipelined dispatch (block only at the end)."""
    def worker():
        outs = [engine.infer(x, block=False) for x in batches]
        outs[-1].block_until_ready()

    ts = [threading.Thread(target=worker) for _ in range(threads)]
    n_images = threads * sum(x.shape[0] for x in batches)
    t0 = time.perf_counter()
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    return n_images / (time.perf_counter() - t0)


def _measure_network(name: str, cache: PlanCache, reps: int,
                     smoke: bool) -> Tuple[dict, List[str]]:
    """One network's serving measurement; returns (summary, failures)."""
    failures: List[str] = []
    zoo = ZOO[name]
    key = jax.random.PRNGKey(0)
    params = lw.init_params(zoo.graph, key, (IN_HW, IN_HW))
    acc = pm.AcceleratorConfig.equal_area("heana", Dataflow.OS, 1.0)
    # bits=6 keeps partial sums bit-exactness-safe (as throughput.py).
    cfg = PhotonicConfig(backend=Backend.HEANA, bits=6, dpe_size=83,
                         noise_enabled=False)
    engine = ServingEngine(params, acc, cfg, lowering=zoo.graph,
                           in_hw=IN_HW, max_batch=MAX_BATCH,
                           plan_cache=cache)
    cold = engine.warmup()
    mk = lambda i, n: jax.random.normal(  # noqa: E731
        jax.random.fold_in(key, i), (n, IN_HW, IN_HW, zoo.in_ch))

    # -- bucketed serving: concurrent warm max-bucket streams --------------
    full = [mk(100 + i, MAX_BATCH) for i in range(reps)]
    engine.infer(full[0])                       # warm the metrics path
    traces0 = trace_count()
    bucketed_ips = _stream_ips(engine, full, STREAM_THREADS)
    # -- mixed-size stream: padding overhead shows up in the stats ---------
    for i, n in enumerate((1, 3, MAX_BATCH)):
        engine.infer(mk(200 + i, n))
    retraces = trace_count() - traces0
    if retraces:
        failures.append(f"{name}: {retraces} retraces across warm bucket "
                        f"reuse — buckets were not pre-traced by warmup")

    # -- per-request baseline: batch-1 plan, one blocking call per image --
    plan1 = engine.plans[1]
    singles = [mk(300 + i, 1) for i in range(4 * reps)]
    execute_cnn(params, singles[0], plan1, cfg,
                lowering=zoo.graph).block_until_ready()    # warm
    t0 = time.perf_counter()
    for x1 in singles:
        execute_cnn(params, x1, plan1, cfg,
                    lowering=zoo.graph).block_until_ready()
    per_request_ips = len(singles) / (time.perf_counter() - t0)

    # -- data-parallel bit-identity (>= 2 devices) -------------------------
    n_dev = len(jax.devices())
    dp_bitexact: Optional[bool] = None
    dp_ips: Optional[float] = None
    if n_dev >= 2 and MAX_BATCH % n_dev == 0:
        dp = ServingEngine(params, acc, cfg, lowering=zoo.graph,
                           in_hw=IN_HW, max_batch=MAX_BATCH,
                           plan_cache=cache, data_parallel=True)
        dp.warmup()
        xb = full[0]
        dp_logits = dp.infer(xb)
        sd_logits = engine.infer(xb)
        dp_bitexact = bool(
            (jax.device_get(dp_logits) == jax.device_get(sd_logits)).all())
        if not dp_bitexact:
            failures.append(f"{name}: data-parallel logits != "
                            f"single-device logits ({n_dev} devices)")
        dp_ips = _stream_ips(dp, full, 1)

    stats = engine.stats()
    summary = serving_summary(
        name, MAX_BATCH, stats, bucketed_ips, per_request_ips,
        extras={"cold_s": cold, "dp_bitexact": dp_bitexact,
                "dp_ips": dp_ips, "retraces_warm": retraces,
                "in_hw": IN_HW, "stream_threads": STREAM_THREADS,
                "smoke": smoke, "bits": cfg.bits,
                "impl": "pallas(interpret,cpu)"})
    return summary, failures


def measure(networks: Sequence[str] = NETWORKS, reps: int = 6,
            save: bool = True, smoke: bool = False,
            ) -> Tuple[List[Row], List[dict], List[str]]:
    """Returns (csv rows, summaries, hard-failure messages)."""
    cache = PlanCache()
    rows: List[Row] = []
    summaries: List[dict] = []
    failures: List[str] = []
    for name in networks:
        summary, fails = _measure_network(name, cache, reps, smoke)
        summaries.append(summary)
        failures.extend(fails)
        if save:
            save_summary(summary, EXP_DIR,
                         f"{name}_b{MAX_BATCH}_d{len(jax.devices())}.json")
        rows.append(Row(f"serving/{name}/bucketed_ips", 0.0,
                        round(summary["bucketed_ips"], 1)))
        rows.append(Row(f"serving/{name}/per_request_ips", 0.0,
                        round(summary["per_request_ips"], 1)))
        rows.append(Row(f"serving/{name}/speedup", 0.0,
                        round(summary["speedup"], 2)))
        rows.append(Row(f"serving/{name}/padding_fraction", 0.0,
                        round(summary["padding_fraction"], 3)))
        rows.append(Row(f"serving/{name}/retraces_warm", 0.0,
                        summary["retraces_warm"]))
        if summary["dp_bitexact"] is not None:
            rows.append(Row(f"serving/{name}/dp_bitexact", 0.0,
                            int(summary["dp_bitexact"])))
    no_retrace = all(s["retraces_warm"] == 0 for s in summaries)
    rows.append(Row("serving/no_retrace_warm", 0.0, int(no_retrace)))
    return rows, summaries, failures


def run() -> List[Row]:
    """benchmarks/run.py entry point (full grid + acceptance floor)."""
    rows, summaries, failures = measure(NETWORKS + FULL_EXTRA_NETWORKS)
    n_fast = sum(1 for s in summaries if s["name"] in NETWORKS
                 and s["speedup"] >= FULL_MIN_SPEEDUP)
    rows.append(Row("serving/ge_5x_on_two_networks", 0.0, int(n_fast >= 2)))
    if failures:
        raise RuntimeError("; ".join(failures))
    return rows


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced reps + CI assertions: zero warm "
                         "retraces, dp bit-identity (when >= 2 devices), "
                         "loose speedup floor; exits nonzero on breach")
    args = ap.parse_args(argv)
    reps = 3 if args.smoke else 6
    networks = (SMOKE_NETWORKS if args.smoke
                else NETWORKS + FULL_EXTRA_NETWORKS)
    rows, summaries, failures = measure(networks, reps=reps,
                                        save=not args.smoke,
                                        smoke=args.smoke)
    for r in rows:
        print(r.csv())
    status = 0
    checked = [s for s in summaries if s["name"] in NETWORKS]
    if args.smoke:
        for s in checked:
            if s["speedup"] < SMOKE_MIN_SPEEDUP:
                print(f"FAIL: {s['name']} bucketed/per-request speedup "
                      f"{s['speedup']:.2f}x < {SMOKE_MIN_SPEEDUP}x floor",
                      file=sys.stderr)
                status = 1
    else:
        n_fast = sum(1 for s in checked
                     if s["speedup"] >= FULL_MIN_SPEEDUP)
        if n_fast < 2:
            print(f"FAIL: only {n_fast} network(s) reached the "
                  f"{FULL_MIN_SPEEDUP}x bucketed/per-request floor "
                  f"(need >= 2): "
                  f"{[(s['name'], round(s['speedup'], 2)) for s in checked]}",
                  file=sys.stderr)
            status = 1
    for f in failures:
        print(f"FAIL: {f}", file=sys.stderr)
        status = 1
    if status == 0:
        print(f"serving: engine OK (zero warm retraces, speedups "
              f"{[round(s['speedup'], 1) for s in summaries]}, dp "
              f"bit-exact {[s['dp_bitexact'] for s in summaries]})")
    return status


if __name__ == "__main__":
    raise SystemExit(main())

"""Shared benchmark utilities: timing + CSV convention.

Every benchmark module exposes ``run() -> list[Row]``; benchmarks/run.py
prints one ``name,us_per_call,derived`` CSV line per row (the scaffold
contract): ``us_per_call`` measures the benchmark's own compute call and
``derived`` carries the headline metric being reproduced.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, List, Optional


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: Any

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


def timed(fn: Callable, *args, repeats: int = 1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeats
    return out, dt * 1e6

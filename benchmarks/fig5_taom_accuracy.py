"""Paper Fig. 5: TAOM accuracy/precision vs optical power and sample rate.

The paper measured these surfaces with Lumerical transient simulations; we
reproduce them from the closed-form noise model (DESIGN.md §6.1): accuracy
is log2(1/MAE) of simulated dot products against ideal, exactly the
paper's metric, evaluated on the analytic TAOM+BPCA simulation.

Expected qualitative trends (asserted by tests/test_benchmarks.py):
  * accuracy rises with optical power,
  * accuracy falls with sample rate (higher DR -> more noise bandwidth),
  * precision (resolvable bits) rises with the time-step size.
"""
from __future__ import annotations

import math
from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import Row, timed
from repro.core import noise as noise_mod
from repro.core.photonic_gemm import photonic_dot_general
from repro.core.types import Backend, OpticalParams, PhotonicConfig


def accuracy_bits(power_dbm: float, dr_gsps: float, bits: int = 8,
                  n: int = 32, trials: int = 8) -> float:
    """log2(1/MAE), MAE normalized to the dot-product full scale."""
    cfg = PhotonicConfig(backend=Backend.HEANA, bits=bits, adc_bits=10,
                         dpe_size=n, data_rate_gsps=dr_gsps,
                         pd_power_dbm=power_dbm)
    key = jax.random.PRNGKey(0)
    maes = []
    for t in range(trials):
        kx, kw, kn = jax.random.split(jax.random.fold_in(key, t), 3)
        x = jax.random.uniform(kx, (8, n), minval=-1, maxval=1)
        w = jax.random.uniform(kw, (n, 8), minval=-1, maxval=1)
        ideal = x @ w
        got = photonic_dot_general(x, w, cfg, key=kn)
        fs = float(jnp.max(jnp.abs(ideal))) + 1e-9
        maes.append(float(jnp.mean(jnp.abs(got - ideal))) / fs)
    mae = max(sum(maes) / len(maes), 1e-9)
    return math.log2(1.0 / mae)


def run() -> List[Row]:
    rows: List[Row] = []
    # 8-bit operands: the receiver is noise-limited (not quantization-
    # limited) across this power range, so the paper's trends are visible.
    powers = (-20.0, -10.0, 0.0, 10.0)
    rates = (1.0, 5.0, 10.0)
    for p in powers:
        for dr in rates:
            acc, us = timed(accuracy_bits, p, dr)
            rows.append(Row(f"fig5/accuracy_bits/p{int(p)}dbm/dr{int(dr)}",
                            us, round(acc, 2)))
    # precision = ENOB from the receiver model (paper's Eq. 1 view)
    o = OpticalParams()
    for p in powers:
        for dr in rates:
            enob, us = timed(noise_mod.enob, p, dr, o)
            rows.append(Row(f"fig5/precision_enob/p{int(p)}dbm/dr{int(dr)}",
                            us, round(enob, 2)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())

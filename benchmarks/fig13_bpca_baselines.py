"""Paper Figs. 13 & 14: HEANA vs BPCA-integrated AMW/MAW baselines.

The BPCA is the paper's portable contribution — bolting it onto the
baselines shrinks HEANA's margin (psum traffic gone) but cannot recover
the thermo-optic weight-actuation cost.  Derived: gmean FPS / FPS/W
ratios vs the *upgraded* baselines, batch 1 and 256.
"""
from __future__ import annotations

from typing import List

from benchmarks.common import Row, timed
from repro.core import perf_model as pm
from repro.core.types import Dataflow
from repro.models.cnn import CNN_ZOO


def _ratios(batch: int, dr: float):
    out = {}
    for base in ("amw", "maw"):
        fps_r, w_r = [], []
        for cnn, fn in CNN_ZOO.items():
            layers = fn()
            h = pm.cnn_inference(layers, pm.AcceleratorConfig.equal_area(
                "heana", Dataflow.OS, dr), batch)
            best_fps = best_w = 0.0
            for flow in Dataflow:
                r = pm.cnn_inference(layers, pm.AcceleratorConfig.equal_area(
                    f"{base}_bpca", flow, dr), batch)
                best_fps = max(best_fps, r.fps)
                best_w = max(best_w, r.fps_per_watt)
            fps_r.append(h.fps / best_fps)
            w_r.append(h.fps_per_watt / best_w)
        out[base] = (pm.gmean(fps_r), pm.gmean(w_r))
    return out


def run() -> List[Row]:
    rows: List[Row] = []
    for batch, fig in ((1, "fig13"), (256, "fig14")):
        for dr in (1.0, 5.0, 10.0):
            res, us = timed(_ratios, batch, dr)
            for base, (fps_g, w_g) in res.items():
                rows.append(Row(f"{fig}/fps/heana_vs_{base}_bpca/dr{int(dr)}",
                                us, round(fps_g, 1)))
                rows.append(Row(
                    f"{fig}/fpsw/heana_vs_{base}_bpca/dr{int(dr)}",
                    us, round(w_g, 1)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())

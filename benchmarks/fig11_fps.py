"""Paper Figs. 11 & 12: FPS and FPS/W vs AMW/MAW, batch 1 and 256.

Derived metrics are the paper's headline gmean ratios: HEANA-OS vs the
best dataflow of each baseline, gmean over the four CNNs.
"""
from __future__ import annotations

from typing import List

from benchmarks.common import Row, timed
from repro.core import perf_model as pm
from repro.core.types import Dataflow
from repro.models.cnn import CNN_ZOO


def _suite(batch: int, dr: float):
    table = {}
    for name, fn in CNN_ZOO.items():
        layers = fn()
        for be in ("heana", "amw", "maw"):
            for flow in Dataflow:
                acc = pm.AcceleratorConfig.equal_area(be, flow, dr)
                table[(name, be, flow.value)] = pm.cnn_inference(
                    layers, acc, batch)
    return table


def run(batches=(1, 256), drs=(1.0, 5.0, 10.0)) -> List[Row]:
    rows: List[Row] = []
    for batch in batches:
        fig = "fig11" if batch == 1 else "fig12"
        for dr in drs:
            table, us = timed(_suite, batch, dr)
            for metric, attr in (("fps", "fps"), ("fpsw", "fps_per_watt")):
                for base in ("amw", "maw"):
                    ratios = []
                    for cnn in CNN_ZOO:
                        h = getattr(table[(cnn, "heana", "os")], attr)
                        b = max(getattr(table[(cnn, base, f.value)], attr)
                                for f in Dataflow)
                        ratios.append(h / b)
                    rows.append(Row(
                        f"{fig}/{metric}/heana_os_vs_{base}/dr{int(dr)}",
                        us, round(pm.gmean(ratios), 1)))
            # absolute FPS of HEANA-OS on ResNet50 (anchor row)
            rows.append(Row(f"{fig}/abs_fps/heana_os/resnet50/dr{int(dr)}",
                            us, round(table[("resnet50", "heana",
                                             "os")].fps, 1)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())

"""Auto-scheduled per-layer dataflows vs fixed dataflows (exec engine).

Three claims, measured:

  * planning: on every CNN in the zoo, at batch 1 and 256, the
    auto-schedule's perf-model FPS is >= the best single fixed dataflow
    (per-layer argmin can only tie or beat a global choice) — and on the
    thermo-optic baselines the mix is genuinely heterogeneous;
  * caching: re-planning the same shapes/config hits the
    content-addressed plan cache 100%;
  * execution: one end-to-end CNN inference through the Pallas TAOM
    kernel equals the pure-jnp reference bit-exactly with noise disabled.

Summaries are cached under experiments/autoflow/ for benchmarks/report.py.
"""
from __future__ import annotations

import os
from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import Row, timed
from repro.core import perf_model as pm
from repro.core.types import Backend, Dataflow, PhotonicConfig
from repro.exec import (PlanCache, execute_cnn, plan_for_network,
                        plan_summary, plan_vs_fixed, reference_forward,
                        schedule_cnn, save_summary)
from repro.models.cnn import CNN_ZOO, build_small_cnn

EXP_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "autoflow")
BACKENDS = ("heana", "amw", "maw")
BATCHES = (1, 256)


def _plan_rows(cache: PlanCache) -> List[Row]:
    rows: List[Row] = []
    all_ok = True
    for be in BACKENDS:
        for batch in BATCHES:
            for name, fn in CNN_ZOO.items():
                layers = fn()
                acc = pm.AcceleratorConfig.equal_area(be, Dataflow.OS, 1.0)
                plan, us = timed(schedule_cnn, layers, acc, batch,
                                 cache=cache)
                fixed = {f: pm.cnn_inference(
                    layers, pm.AcceleratorConfig.equal_area(be, f, 1.0),
                    batch).fps for f in Dataflow}
                cmp = plan_vs_fixed(plan, fixed)
                ok = plan.fps >= cmp["best_fixed_fps"] * (1 - 1e-12)
                all_ok &= ok
                summary = plan_summary(plan, name)
                summary["vs_fixed"] = cmp
                save_summary(summary, EXP_DIR, f"{be}_{name}_b{batch}.json")
                rows.append(Row(f"autoflow/{be}/{name}/b{batch}/uplift",
                                us, round(cmp["uplift"], 4)))
                mix = plan.mix()
                rows.append(Row(f"autoflow/{be}/{name}/b{batch}/mix_os_is_ws",
                                us, f"{mix['os']}-{mix['is']}-{mix['ws']}"))
    rows.append(Row("autoflow/auto_ge_best_fixed_all", 0.0, int(all_ok)))
    return rows


def _cache_rows(cache: PlanCache) -> List[Row]:
    """Re-plan the whole grid: every layer plan must be a cache hit."""
    hits = misses = 0
    for be in BACKENDS:
        for batch in BATCHES:
            for name, fn in CNN_ZOO.items():
                acc = pm.AcceleratorConfig.equal_area(be, Dataflow.OS, 1.0)
                plan = schedule_cnn(fn(), acc, batch, cache=cache)
                hits += plan.cache_hits
                misses += plan.cache_misses
    rate = hits / max(hits + misses, 1)
    return [Row("autoflow/cache/replan_hit_rate", 0.0, round(rate, 4)),
            Row("autoflow/cache/entries", 0.0, cache.stats()["entries"])]


def _exec_rows() -> List[Row]:
    """End-to-end small-CNN inference through the Pallas kernel."""
    key = jax.random.PRNGKey(0)
    params = build_small_cnn(key)
    batch = 4
    x = jax.random.normal(jax.random.fold_in(key, 1), (batch, 16, 16, 3))
    acc = pm.AcceleratorConfig.equal_area("heana", Dataflow.OS, 1.0)
    # bits=6 keeps every integer partial sum < 2^24, so float summation
    # order cannot break the bit-exactness contract at any K here.
    cfg = PhotonicConfig(backend=Backend.HEANA, bits=6, dpe_size=83,
                         noise_enabled=False)
    plan = plan_for_network(params, acc, batch=batch)
    res, us = timed(execute_cnn, params, x, plan, cfg, impl="pallas")
    ref = reference_forward(params, x, cfg)
    exact = bool(jnp.all(res.logits == ref))
    from repro.exec import execution_summary
    summary = execution_summary(res, "small_cnn", numerics={
        "bitexact_vs_ref": exact,
        "max_abs_diff": float(jnp.max(jnp.abs(res.logits - ref))),
        "batch": batch, "bits": cfg.bits})
    save_summary(summary, EXP_DIR, "exec_small_cnn.json")
    return [
        Row("autoflow/exec/small_cnn/bitexact_vs_ref", us, int(exact)),
        Row("autoflow/exec/small_cnn/us_per_image", us / batch,
            round(res.plan.fps, 1)),
    ]


def run() -> List[Row]:
    cache = PlanCache()
    rows = _plan_rows(cache)
    rows += _cache_rows(cache)
    rows += _exec_rows()
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())

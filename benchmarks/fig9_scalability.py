"""Paper Fig. 9 + Table 2: achievable DPU size N(B, DR) per organization."""
from __future__ import annotations

from typing import List

from benchmarks.common import Row, timed
from repro.core import scalability

PAPER_ANCHORS = {  # (backend, bits, dr) -> paper N
    ("heana", 4, 1.0): 83, ("heana", 4, 5.0): 42, ("heana", 4, 10.0): 30,
    ("amw", 4, 1.0): 36, ("amw", 4, 5.0): 17, ("amw", 4, 10.0): 12,
    ("maw", 4, 1.0): 43, ("maw", 4, 5.0): 21, ("maw", 4, 10.0): 15,
}


def run() -> List[Row]:
    rows: List[Row] = []
    surface, us = timed(scalability.fig9_surface)
    for (be, b, dr), n in sorted(surface.items()):
        rows.append(Row(f"fig9/{be}/b{b}/dr{int(dr)}", us / len(surface), n))
    hits = sum(1 for k, v in PAPER_ANCHORS.items()
               if abs(surface[k] - v) <= 1)
    rows.append(Row("fig9/anchors_within_1", us, f"{hits}/9"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())

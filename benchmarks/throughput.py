"""Serving-throughput benchmark: compiled vs eager executor hot path.

The pre-fix executor stalled the device on the HOST every layer
(per-layer ``float(jnp.mean(...))`` syncs) and re-traced every inference
(no jit boundary around the per-layer ``pl.pallas_call``s) — the exact
stalls HEANA's buffer-less in-situ accumulation is designed to avoid on
the real hardware (paper §5, BPCA).  This module measures the fix:

  * warm-call images/sec of the jit-compiled forward
    (exec.compiled_forward) vs the eager op-by-op path
    (execute_cnn(compiled=False)) at batch {1, 32, 256};
  * a no-retrace assertion — warm compiled calls must leave the trace
    counter untouched (exec.trace_count), so the compiled path cannot
    silently regress to eager/retracing;
  * compiled == eager logits bitwise (the numerics contract rides along).

Summaries are cached under experiments/throughput/ for
benchmarks/report.py (§Throughput).  ``--smoke`` runs a small-batch
subset with the same assertions for CI; it exits nonzero on regression.

NOTE on units: images/sec here is the HOST SIMULATION throughput (Pallas
kernel in interpret mode on CPU) — it validates the software hot path.
``modeled_fps`` in the JSONs is the photonic perf-model number for the
same plan; the two are different machines and never directly comparable.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from typing import List, Optional, Sequence, Tuple

import jax

from benchmarks.common import Row
from repro.core import perf_model as pm
from repro.core.types import Backend, Dataflow, PhotonicConfig
from repro.exec import (PlanCache, compiled_forward, execute_cnn,
                        plan_for_network, save_summary, throughput_summary,
                        trace_count)
from repro.models.cnn import build_small_cnn

EXP_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "throughput")
BATCHES = (1, 32, 256)
SMOKE_BATCHES = (1, 32)
# Acceptance floor (ISSUE 2): warm compiled must beat eager by >= 5x at
# batch 256.  The smoke floor is looser — CI boxes are noisy — but still
# far above 1.0, so a silent regression to eager (speedup ~1) trips it.
FULL_MIN_SPEEDUP_B256 = 5.0
SMOKE_MIN_SPEEDUP = 2.0


def _time_calls(fn, reps: int) -> float:
    """Median-free best-effort timing: total wall over ``reps`` calls."""
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def _reps_for(batch: int, eager: bool) -> int:
    if eager:
        return 1 if batch >= 256 else 2
    return {1: 20, 32: 5}.get(batch, 3)


def measure(batches: Sequence[int] = BATCHES,
            save: bool = True) -> Tuple[List[Row], List[dict], List[str]]:
    """Returns (csv rows, summaries, hard-failure messages)."""
    key = jax.random.PRNGKey(0)
    params = build_small_cnn(key)
    acc = pm.AcceleratorConfig.equal_area("heana", Dataflow.OS, 1.0)
    # bits=6 keeps every integer partial sum < 2^24 (bit-exactness safe).
    cfg = PhotonicConfig(backend=Backend.HEANA, bits=6, dpe_size=83,
                         noise_enabled=False)
    cache = PlanCache()
    rows: List[Row] = []
    summaries: List[dict] = []
    failures: List[str] = []

    for batch in batches:
        x = jax.random.normal(jax.random.fold_in(key, batch),
                              (batch, 16, 16, 3))
        plan = plan_for_network(params, acc, batch=batch, cache=cache)
        fn = compiled_forward(plan, cfg)

        # Cold call compiles; everything after must hit the executable.
        t0 = time.perf_counter()
        fn(params, x, None)[0].block_until_ready()
        cold_s = time.perf_counter() - t0

        traces_before = trace_count()
        reps = _reps_for(batch, eager=False)
        warm_s = _time_calls(
            lambda: fn(params, x, None)[0].block_until_ready(), reps)
        new_traces = trace_count() - traces_before
        if new_traces:
            failures.append(
                f"b{batch}: {new_traces} retraces across {reps} warm "
                f"compiled calls — the compiled path regressed to "
                f"retracing")

        eager_s = _time_calls(
            lambda: execute_cnn(params, x, plan, cfg, compiled=False)
            .block_until_ready(), _reps_for(batch, eager=True))

        # Numerics contract rides along: compiled == eager bitwise.
        c_logits = fn(params, x, None)[0]
        e_logits = execute_cnn(params, x, plan, cfg,
                               compiled=False).logits
        bitexact = bool((c_logits == e_logits).all())
        if not bitexact:
            failures.append(f"b{batch}: compiled logits != eager logits")

        compiled_ips = batch / warm_s
        eager_ips = batch / eager_s
        speedup = compiled_ips / eager_ips
        summary = throughput_summary(
            "small_cnn", batch, compiled_ips, eager_ips, plan.fps,
            extras={"cold_s": cold_s, "warm_s": warm_s,
                    "eager_s": eager_s, "bitexact": bitexact,
                    "retraces_warm": new_traces, "bits": cfg.bits,
                    "impl": "pallas(interpret,cpu)"})
        summaries.append(summary)
        if save:
            save_summary(summary, EXP_DIR, f"small_cnn_b{batch}.json")
        rows.append(Row(f"throughput/small_cnn/b{batch}/compiled_ips",
                        warm_s * 1e6, round(compiled_ips, 1)))
        rows.append(Row(f"throughput/small_cnn/b{batch}/eager_ips",
                        eager_s * 1e6, round(eager_ips, 1)))
        rows.append(Row(f"throughput/small_cnn/b{batch}/speedup",
                        warm_s * 1e6, round(speedup, 2)))
        rows.append(Row(f"throughput/small_cnn/b{batch}/bitexact",
                        0.0, int(bitexact)))

    no_retrace = not any("retrace" in f for f in failures)
    rows.append(Row("throughput/no_retrace_warm", 0.0, int(no_retrace)))
    return rows, summaries, failures


def run() -> List[Row]:
    """benchmarks/run.py entry point (full grid)."""
    rows, summaries, failures = measure(BATCHES)
    b256 = next((s for s in summaries if s["batch"] == 256), None)
    if b256 is not None:
        ok = b256["speedup"] >= FULL_MIN_SPEEDUP_B256
        rows.append(Row("throughput/b256_speedup_ge_5x", 0.0, int(ok)))
    if failures:
        raise RuntimeError("; ".join(failures))
    return rows


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small-batch subset + assertions for CI; exits "
                         "nonzero if the compiled path regressed")
    args = ap.parse_args(argv)
    batches = SMOKE_BATCHES if args.smoke else BATCHES
    rows, summaries, failures = measure(batches, save=not args.smoke)
    for r in rows:
        print(r.csv())
    status = 0
    for s in summaries:
        floor = SMOKE_MIN_SPEEDUP if args.smoke else (
            FULL_MIN_SPEEDUP_B256 if s["batch"] == 256 else 1.0)
        if s["speedup"] < floor:
            print(f"FAIL: b{s['batch']} compiled/eager speedup "
                  f"{s['speedup']:.2f}x < {floor}x floor", file=sys.stderr)
            status = 1
    for f in failures:
        print(f"FAIL: {f}", file=sys.stderr)
        status = 1
    if status == 0:
        print("throughput: compiled path OK (no retraces, bit-exact, "
              f"speedups {[round(s['speedup'], 1) for s in summaries]})")
    return status


if __name__ == "__main__":
    raise SystemExit(main())

"""Executed-trace energy & FPS/W accounting from one operating point
(ISSUE 5).

Everything in one cell derives from a single ``core.hw.OperatingPoint``
— DPE size N from the scalability solver, detection sigma from the link
budget, per-event energies from Table 3 — which fans out into the kernel
``PhotonicConfig``, the scheduler plans (plan v4 embeds the point), and
the executed-trace energy accounting.  Two claims are exercised:

  * **Coherence** — every zoo network, actually executed through the
    compiled Pallas path at its operating point, reports executed-trace
    FPS and FPS/W that match the analytic ``perf_model.cnn_inference``
    prediction (same per-layer dataflows) within ``COHERENCE_RTOL``.
    This is coherence *by construction*: one gemm_cost accounting path
    charges both sides, so any gap means plan/lowering/batch-folding
    drift — exactly the silent divergence the OperatingPoint refactor
    exists to make impossible.

  * **Equal-area headline** — the paper's gmean anchors over the four
    full-size evaluation CNNs at the Table 2 area-matched points:
    HEANA-OS vs the best dataflow of each baseline must keep >= 66x FPS
    (abstract) and reproduce the FPS/W anchors (89x vs AMW, 84x vs MAW,
    Fig. 11b) within the repo's documented 25% calibration tolerance
    (DESIGN.md §6 — the same gate tests/test_benchmarks.py applies to
    fig11).

``--smoke`` executes one network plus the (cheap, analytic) headline
gates and exits nonzero on any contract breach — the CI energy-smoke
job.  Full runs execute all four mini networks and cache JSONs under
experiments/energy/ for benchmarks/report.py's §Energy table.
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import List, Optional, Sequence

import jax

from benchmarks.common import Row, timed
from repro.core import hw
from repro.core import perf_model as pm
from repro.core.types import Dataflow
from repro.exec import PlanCache, energy_summary, execute_cnn, \
    plan_for_network, save_summary
from repro.models.cnn import CNN_ZOO
from repro.models.zoo_cnn import PAPER_ZOO, ZOO

EXP_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "energy")

#: Executed-trace vs analytic relative tolerance.  Both sides run the
#: same event accounting; the only admissible gap is float summation
#: order across the per-layer loop.
COHERENCE_RTOL = 1e-9

#: FPS/W anchor calibration tolerance (DESIGN.md §6): the 0.05-FSR
#: tuning-excursion constant was calibrated once against the Fig. 11b
#: gmean anchors and held fixed; predictions must stay within 25%.
FPSW_ANCHORS = {"amw": 89.0, "maw": 84.0}
FPSW_CAL_TOL = 0.75
FPS_FLOOR = 66.0


def _headline_rows(dr: float = 1.0) -> List[Row]:
    """The equal-area gmean anchors over the FULL-SIZE evaluation CNNs,
    every cell derived from an OperatingPoint (analytic — these networks
    are far beyond what the host simulation executes)."""
    rows: List[Row] = []

    def suite():
        table = {}
        for name, fn in CNN_ZOO.items():
            layers = fn()
            for be in ("heana", "amw", "maw"):
                # HEANA is compared as HEANA-OS (the paper's headline);
                # only the baselines get their best-of-three dataflow.
                flows = (Dataflow.OS,) if be == "heana" else tuple(Dataflow)
                for flow in flows:
                    op = hw.OperatingPoint.equal_area(be, flow, dr)
                    table[(name, be, flow.value)] = pm.cnn_inference(
                        layers, op.accelerator_config())
        return table

    table, us = timed(suite)
    for metric, attr in (("fps", "fps"), ("fpsw", "fps_per_watt")):
        for base in ("amw", "maw"):
            ratios = []
            for cnn in CNN_ZOO:
                h = getattr(table[(cnn, "heana", "os")], attr)
                b = max(getattr(table[(cnn, base, f.value)], attr)
                        for f in Dataflow)
                ratios.append(h / b)
            rows.append(Row(f"energy/equal_area/{metric}/"
                            f"heana_os_vs_{base}/dr{int(dr)}",
                            us, round(pm.gmean(ratios), 2)))
    return rows


def _check_headline(rows: Sequence[Row]) -> List[str]:
    vals = {r.name.split("energy/equal_area/")[1]: r.derived for r in rows
            if "equal_area" in r.name}
    probs = []
    for base in ("amw", "maw"):
        fps = vals[f"fps/heana_os_vs_{base}/dr1"]
        fpsw = vals[f"fpsw/heana_os_vs_{base}/dr1"]
        if fps < FPS_FLOOR:
            probs.append(f"fps gmean vs {base} = {fps} < {FPS_FLOOR}")
        if fpsw < FPSW_CAL_TOL * FPSW_ANCHORS[base]:
            probs.append(f"fps/W gmean vs {base} = {fpsw} < "
                         f"{FPSW_CAL_TOL} * {FPSW_ANCHORS[base]} anchor")
    return probs


def _executed_cell(name: str, batch: int = 1, seed: int = 0):
    """Execute one zoo network at the HEANA equal-area operating point
    and return (summary dict, coherence problems)."""
    model = ZOO[name]
    op = hw.OperatingPoint.equal_area("heana", Dataflow.OS, 1.0,
                                      noise_enabled=False)
    params = model.init_params(jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(seed), 1),
                          (batch, *model.in_hw, model.in_ch))
    plan = plan_for_network(params, op, batch=batch, in_hw=model.in_hw,
                            lowering=model.graph, cache=PlanCache())
    res = execute_cnn(params, x, plan, op.kernel_config(),
                      impl="pallas", lowering=model.graph)
    res.block_until_ready()
    executed = res.energy()
    analytic = pm.cnn_inference(model.gemms(params), plan.acc, batch=batch,
                                dataflows=list(plan.dataflows),
                                optics=op.optics)
    summary = energy_summary(name, op, executed, analytic,
                             extras={"dataflow_mix": plan.mix()})
    probs = []
    for key, tol in (("fps_rel_gap", COHERENCE_RTOL),
                     ("fpsw_rel_gap", COHERENCE_RTOL)):
        if summary[key] > tol:
            probs.append(f"{name}: executed-trace {key} = "
                         f"{summary[key]:.3e} > {tol} — the executed "
                         f"system diverged from the analytic model")
    return summary, probs


def _run_cells(networks: Sequence[str], batch: int, save: bool
               ) -> tuple:
    """One shared driver for run() and main(): headline gates + executed
    cells.  Returns (rows, problems); a breached cell's summary is NEVER
    cached (report.py's table promises the 1e-9 gap)."""
    rows = _headline_rows()
    problems = _check_headline(rows)
    for name in networks:
        summary, probs = _executed_cell(name, batch=batch)
        problems += probs
        if save and not probs:
            save_summary(summary, EXP_DIR, f"exec_{name}_b{batch}.json")
        rows.append(Row(f"energy/executed/{name}/fps", 0.0,
                        round(summary["executed_fps"], 1)))
        rows.append(Row(f"energy/executed/{name}/fps_per_watt", 0.0,
                        round(summary["executed_fps_per_watt"], 2)))
        rows.append(Row(f"energy/executed/{name}/uj_per_image", 0.0,
                        round(summary["executed_j_per_image"] * 1e6, 3)))
        rows.append(Row(f"energy/executed/{name}/coherence_rel_gap", 0.0,
                        f"{max(summary['fps_rel_gap'], summary['fpsw_rel_gap']):.1e}"))
    return rows, problems


def run(networks: Optional[Sequence[str]] = None, batch: int = 1,
        save: bool = True) -> List[Row]:
    """Harness entry (benchmarks.run): raises on any contract breach so
    the aggregator's per-module error handling reports it (exit 1 +
    <tag>/ERROR row) instead of silently caching breached JSONs."""
    networks = list(networks if networks is not None else PAPER_ZOO)
    rows, problems = _run_cells(networks, batch, save)
    if problems:
        raise RuntimeError("energy contract breach: " + "; ".join(problems))
    return rows


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="one executed network + analytic headline gates; "
                         "nonzero exit on any contract breach (CI)")
    ap.add_argument("--batch", type=int, default=1)
    args = ap.parse_args()

    networks = ["resnet_mini"] if args.smoke else list(PAPER_ZOO)
    rows, problems = _run_cells(networks, args.batch, save=True)
    for r in rows:
        print(r.csv())

    if problems:
        print("ENERGY CONTRACT BREACH:", file=sys.stderr)
        for p in problems:
            print(f"  - {p}", file=sys.stderr)
        return 1
    print("energy contracts hold: equal-area anchors reproduced, "
          "executed-trace coherent with the analytic model")
    return 0


if __name__ == "__main__":
    sys.exit(main())

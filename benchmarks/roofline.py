import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ must precede jax import: the probes lower on the 16x16 production mesh.

"""Roofline analysis (deliverable g): three terms per (arch x shape).

Sources:
  * probe compiles — XLA cost_analysis counts lax.scan bodies ONCE, so the
    full-model dry-run FLOPs under-count deep stacks.  We therefore lower
    *unrolled* probe configs (every layer group at 1 and at 2 repeats; the
    zoo unrolls groups with <=4 repeats) and reconstruct:
        m_full = m_base + sum_g body_g * repeats_g,
        body_g = m(probe_g) - m(probe_0),   m_base = m(probe_0) - sum body_g
    This applies to per-device FLOPs, bytes accessed, and collective bytes
    alike.  cost_analysis is PER-DEVICE on this backend (verified against a
    hand-counted sharded matmul), so global = per_device * n_devices.
  * hardware constants — TPU v5e: 197 TFLOP/s bf16, 819 GB/s HBM,
    ~50 GB/s/link ICI (core.types.TPU_V5E).

Terms (seconds, per training/serving step):
  compute    = flops_global / (chips * peak)
  memory     = bytes_global / (chips * hbm_bw)
  collective = coll_bytes_global / (chips * link_bw)

plus MODEL_FLOPS (6*N*D dense / 6*N_active*D MoE; 2*N*D prefill; 2*N*B
decode) and the MODEL/HLO ratio.

Writes experiments/roofline/<arch>__<shape>.json.  Run standalone:
  PYTHONPATH=src python -m benchmarks.roofline [--arch A] [--shape S]
"""
import argparse
import dataclasses
import json
import time
from typing import Dict, List, Tuple

import jax

from repro.configs import SHAPES, cell_is_supported, get_config, list_archs
from repro.configs.base import ArchConfig
from repro.core.types import TPU_V5E
from repro.launch import dryrun
from repro.launch.mesh import make_production_mesh
from repro.models import model_zoo as zoo

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "roofline")


# ---------------------------------------------------------------------------
# Layer-group probe plans
# ---------------------------------------------------------------------------
def group_repeats(cfg: ArchConfig) -> Dict[str, int]:
    """Group name -> repeats in the full model."""
    if cfg.family == "audio":
        return {"enc": cfg.encoder_layers, "dec": cfg.num_layers}
    from repro.models.transformer import layer_plan
    return {g.name: g.repeats for g in layer_plan(cfg)}


def cfg_with_repeats(cfg: ArchConfig, reps: Dict[str, int]) -> ArchConfig:
    if cfg.family == "audio":
        return dataclasses.replace(cfg, encoder_layers=reps["enc"],
                                   num_layers=reps["dec"])
    if cfg.family == "hybrid":
        p = cfg.shared_attn_period
        n = p * reps.get("hybrid", 0) + reps.get("tail", 0)
        return dataclasses.replace(cfg, num_layers=n)
    if cfg.local_global_period:
        return dataclasses.replace(
            cfg, num_layers=cfg.local_global_period * reps["localglobal"])
    if cfg.moe is not None:
        fd = reps.get("dense_head", 0)
        return dataclasses.replace(
            cfg, num_layers=fd + reps["moe_body"],
            moe=dataclasses.replace(cfg.moe, first_dense_layers=fd))
    # single-group families (dense/ssm/vlm): whatever the group is named
    (only_group,) = reps.values()
    return dataclasses.replace(cfg, num_layers=only_group)


def probe_plan(cfg: ArchConfig) -> Tuple[Dict[str, int], List[Dict[str, int]]]:
    """(full repeats, probe repeat-maps).  probe[0] = all groups at 1."""
    full = group_repeats(cfg)
    base = {g: 1 for g in full}
    probes = [base]
    for g in full:
        if full[g] > 1:
            probes.append({**base, g: 2})
    return full, probes


# ---------------------------------------------------------------------------
# MODEL_FLOPS
# ---------------------------------------------------------------------------
def param_counts(cfg: ArchConfig) -> Tuple[int, int]:
    """(total, active) non-embedding params."""
    abs_params = zoo.init_params(cfg, jax.random.PRNGKey(0), abstract=True)
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(abs_params)[0]:
        keys = "/".join(str(getattr(p, "key", "")) for p in path)
        if "embed" in keys or "lm_head" in keys:
            continue
        total += leaf.size
    active = total
    if cfg.moe is not None:
        moe_layers = cfg.num_layers - cfg.moe.first_dense_layers
        per_expert = 3 * cfg.d_model * cfg.moe.d_ff_expert
        inactive = (cfg.moe.num_experts - cfg.moe.experts_per_token) * \
            per_expert * moe_layers
        active = total - inactive
    return total, active


def model_flops(cfg: ArchConfig, shape) -> float:
    _, active = param_counts(cfg)
    if shape.kind == "train":
        return 6.0 * active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * active * shape.global_batch * shape.seq_len
    return 2.0 * active * shape.global_batch        # one token per request


# ---------------------------------------------------------------------------
# Probe measurement
# ---------------------------------------------------------------------------
def measure(cfg: ArchConfig, shape, mesh) -> Dict[str, float]:
    fn, args, in_sh, donate = dryrun.build_step(cfg, shape, mesh)
    with mesh:
        compiled = jax.jit(fn, in_shardings=in_sh,
                           donate_argnums=donate).lower(*args).compile()
        cost = compiled.cost_analysis()
        coll = dryrun.collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": float(coll["total_bytes"]),
            "convert": float(dryrun.convert_bytes(compiled.as_text())),
            "coll_by_kind": coll["bytes"]}


def reconstruct(cfg: ArchConfig, shape, mesh) -> Dict[str, float]:
    full, probes = probe_plan(cfg)
    ms = [measure(cfg_with_repeats(cfg, p), shape, mesh) for p in probes]
    base_keys = ("flops", "bytes", "coll", "convert")
    m0 = ms[0]
    bodies: Dict[str, Dict[str, float]] = {}
    idx = 1
    for g in full:
        if full[g] > 1:
            bodies[g] = {k: max(0.0, ms[idx][k] - m0[k]) for k in base_keys}
            idx += 1
        else:
            bodies[g] = {k: 0.0 for k in base_keys}
    out = {}
    for k in base_keys:
        # probe_0 contains every group once; add (repeats-1) more bodies.
        out[k] = m0[k] + sum(bodies[g][k] * (full[g] - 1) for g in full
                             if full[g] > 1)
    out["coll_by_kind"] = m0["coll_by_kind"]
    out["probes"] = len(probes)
    return out


def run_cell(arch: str, shape_name: str, force: bool = False) -> dict:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{arch}__{shape_name}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_is_supported(cfg, shape)
    rec = {"arch": arch, "shape": shape_name}
    if not ok:
        rec.update(status="skipped", reason=why)
    else:
        t0 = time.time()
        try:
            mesh = make_production_mesh(multi_pod=False)
            chips = mesh.devices.size
            m = reconstruct(cfg, shape, mesh)
            flops_g = m["flops"] * chips
            bytes_g = m["bytes"] * chips
            coll_g = m["coll"] * chips
            # TPU-adjusted bytes: remove XLA:CPU's bf16-emulation converts
            # (f32 output + bf16 input = 1.5x output bytes) — see
            # dryrun.convert_bytes.
            bytes_adj_g = max(bytes_g - 1.5 * m["convert"] * chips,
                              0.25 * bytes_g)
            t_comp = flops_g / (chips * TPU_V5E.peak_flops_bf16)
            t_mem = bytes_g / (chips * TPU_V5E.hbm_bandwidth)
            t_mem_adj = bytes_adj_g / (chips * TPU_V5E.hbm_bandwidth)
            t_coll = coll_g / (chips * TPU_V5E.ici_link_bandwidth)
            terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
            dominant = max(terms, key=terms.get)
            mf = model_flops(cfg, shape)
            total_p, active_p = param_counts(cfg)
            rec.update(
                status="ok", chips=chips,
                hlo_flops_global=flops_g, hlo_bytes_global=bytes_g,
                collective_bytes_global=coll_g,
                coll_by_kind_per_dev=m["coll_by_kind"],
                compute_s=t_comp, memory_s=t_mem, memory_s_tpu_adj=t_mem_adj,
                collective_s=t_coll, dominant=dominant,
                model_flops=mf, model_hlo_ratio=mf / max(flops_g, 1.0),
                params_total=total_p, params_active=active_p,
                roofline_fraction=t_comp / max(t_comp, t_mem, t_coll),
                probe_compiles=m["probes"],
                wall_s=round(time.time() - t0, 1),
            )
        except Exception as e:  # noqa: BLE001
            import traceback
            rec.update(status="error", error=f"{type(e).__name__}: {e}",
                       traceback=traceback.format_exc()[-1500:])
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    archs = [args.arch] if args.arch else list(list_archs())
    shapes = [args.shape] if args.shape else list(SHAPES)
    for arch in archs:
        for shape in shapes:
            rec = run_cell(arch, shape, args.force)
            if rec["status"] == "ok":
                print(f"{arch:24s} {shape:12s} dominant={rec['dominant']:10s}"
                      f" comp={rec['compute_s']:.3e}s"
                      f" mem={rec['memory_s']:.3e}s"
                      f" coll={rec['collective_s']:.3e}s"
                      f" model/hlo={rec['model_hlo_ratio']:.2f}", flush=True)
            else:
                print(f"{arch:24s} {shape:12s} {rec['status']}: "
                      f"{rec.get('reason', rec.get('error', ''))[:80]}",
                      flush=True)


if __name__ == "__main__":
    main()

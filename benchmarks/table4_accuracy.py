"""Paper Table 4: inference accuracy under analog photonic numerics.

Offline proxy for the ImageNet experiment (DESIGN.md §6.2): a small CNN is
trained (exact numerics, f32) on a synthetic 10-class image task, then
evaluated with its conv/fc GEMMs executed as:

    exact | int8 quantized | HEANA (8-bit, analog carry + noise) |
    MAW (8-bit, per-chunk ADC + noise)

Derived: top-1 accuracy and the drop vs exact — the paper's claim is a
<=0.1% drop for HEANA at 8-bit; our proxy shows the same near-zero drop
ordering (HEANA drop <= MAW drop).
"""
from __future__ import annotations

import functools
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, timed
from repro.core.photonic_gemm import design_point
from repro.core.types import Backend, PhotonicConfig
from repro.kernels import ops as kops
from repro.models.cnn import build_small_cnn, small_cnn_apply

HW, NCLASS = 16, 10


_TEMPLATES = jax.random.normal(jax.random.PRNGKey(42), (NCLASS, HW, HW, 3))


def make_data(n: int, key, noise=2.5):
    """FIXED class templates + Gaussian noise: a learnable 10-way task."""
    nkey, lkey = jax.random.split(key)
    labels = jax.random.randint(lkey, (n,), 0, NCLASS)
    x = _TEMPLATES[labels] + noise * jax.random.normal(nkey, (n, HW, HW, 3))
    return x, labels


def train_model(steps=150, lr=0.05, batch=64, seed=0):
    key = jax.random.PRNGKey(seed)
    params = build_small_cnn(jax.random.fold_in(key, 1), NCLASS, HW)

    @jax.jit
    def step(params, x, y):
        def loss_fn(p):
            logits = small_cnn_apply(p, x)
            return -jnp.mean(jnp.take_along_axis(
                jax.nn.log_softmax(logits), y[:, None], axis=1))
        loss, g = jax.value_and_grad(loss_fn)(params)
        return jax.tree.map(lambda p, gi: p - lr * gi, params, g), loss

    for s in range(steps):
        x, y = make_data(batch, jax.random.fold_in(key, 1000 + s))
        params, loss = step(params, x, y)
    return params


def evaluate(params, numerics: str, n=512, seed=123) -> float:
    x, y = make_data(n, jax.random.PRNGKey(seed))
    if numerics == "exact":
        mm = None
    else:
        if numerics == "int8":
            cfg = PhotonicConfig(backend=Backend.INT_QUANT, bits=8,
                                 noise_enabled=False)
        elif numerics == "heana":
            cfg = design_point(Backend.HEANA, 8, 1.0, adc_bits=12)
        else:
            cfg = design_point(Backend.MAW, 8, 1.0, adc_bits=12)
        mm = functools.partial(kops.photonic_matmul, cfg=cfg,
                               key=jax.random.PRNGKey(7), impl="ref")
        mm = lambda a, w, _f=mm: _f(a, w)  # noqa: E731
    logits = small_cnn_apply(params, x, matmul=mm)
    return float(jnp.mean(jnp.argmax(logits, -1) == y))


def run() -> List[Row]:
    rows: List[Row] = []
    params, us_train = timed(train_model)
    accs = {}
    for mode in ("exact", "int8", "heana", "maw"):
        acc, us = timed(evaluate, params, mode)
        accs[mode] = acc
        rows.append(Row(f"table4/top1/{mode}", us, round(acc, 4)))
    for mode in ("int8", "heana", "maw"):
        rows.append(Row(f"table4/top1_drop_pct/{mode}", us_train,
                        round(100 * (accs["exact"] - accs[mode]), 2)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())

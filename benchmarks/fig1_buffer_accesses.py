"""Paper Fig. 1 table: buffer accesses per dataflow, GoogleNet layer 5."""
from __future__ import annotations

from typing import List

from benchmarks.common import Row, timed
from repro.core import dataflow as df
from repro.models.cnn import googlenet_layer5


def run() -> List[Row]:
    l5 = googlenet_layer5()
    g = df.GemmShape(l5.c, l5.k, l5.d)
    rows: List[Row] = []
    for bpca in (False, True):
        table, us = timed(df.fig1_table, g, 83, bpca)
        tag = "bpca" if bpca else "nobpca"
        for flow, counts in table.items():
            rows.append(Row(f"fig1/{tag}/{flow}/total", us, counts["total"]))
            rows.append(Row(f"fig1/{tag}/{flow}/psum", us,
                            counts["psum_accesses"]))
    # orderings the paper's table demonstrates
    t = df.fig1_table(g, 83, False)
    rows.append(Row("fig1/ws_min_weight_reads", 0.0,
                    int(t["ws"]["weight_reads"] ==
                        min(x["weight_reads"] for x in t.values()))))
    rows.append(Row("fig1/is_min_input_reads", 0.0,
                    int(t["is"]["input_reads"] ==
                        min(x["input_reads"] for x in t.values()))))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())

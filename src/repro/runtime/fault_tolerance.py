"""Fault tolerance & elasticity for long-running multi-pod training.

Pieces (all exercised by tests/test_runtime.py):

  * HeartbeatMonitor — tracks per-host liveness; a host that misses
    ``dead_after`` seconds of beats is declared failed.  On real clusters
    the beats come from the coordination service; the logic is identical.
  * StragglerPolicy — per-step duration tracking with a robust (median +
    k*MAD) deadline; hosts that exceed it repeatedly are flagged for
    replacement BEFORE they fail hard (slow HBM, thermal throttle).
  * run_resilient_loop — the supervisor: run step -> on failure, shrink or
    re-mesh -> restore from the last atomic checkpoint -> continue.  The
    deterministic data pipeline (seed, step) makes recovery bit-exact.
  * plan_elastic_remesh — given surviving device count, pick the largest
    (data, model) mesh that preserves the model sharding (model axis is
    kept; data axis shrinks), and the batch reshard plan.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Dict, List, Optional, Tuple


class HeartbeatMonitor:
    def __init__(self, hosts: List[str], dead_after: float = 60.0,
                 clock: Callable[[], float] = time.monotonic):
        self.dead_after = dead_after
        self.clock = clock
        self.last_beat: Dict[str, float] = {h: clock() for h in hosts}

    def beat(self, host: str) -> None:
        self.last_beat[host] = self.clock()

    def dead_hosts(self) -> List[str]:
        now = self.clock()
        return [h for h, t in self.last_beat.items()
                if now - t > self.dead_after]

    def all_alive(self) -> bool:
        return not self.dead_hosts()


class StragglerPolicy:
    """Flag hosts whose step times are persistent outliers."""

    def __init__(self, tolerance: float = 3.0, window: int = 32,
                 strikes_to_flag: int = 3):
        self.tolerance = tolerance
        self.window = window
        self.strikes_to_flag = strikes_to_flag
        self.history: Dict[str, List[float]] = {}
        self.strikes: Dict[str, int] = {}

    def record(self, host: str, step_time: float) -> None:
        h = self.history.setdefault(host, [])
        h.append(step_time)
        if len(h) > self.window:
            h.pop(0)

    def deadline(self) -> Optional[float]:
        all_times = sorted(t for h in self.history.values() for t in h)
        if len(all_times) < 8:
            return None
        mid = all_times[len(all_times) // 2]
        mad = sorted(abs(t - mid) for t in all_times)[len(all_times) // 2]
        return mid + self.tolerance * max(mad, 0.05 * mid)

    def update_strikes(self) -> List[str]:
        """Call once per step after records; returns flagged hosts."""
        dl = self.deadline()
        if dl is None:
            return []
        flagged = []
        for host, h in self.history.items():
            if h and h[-1] > dl:
                self.strikes[host] = self.strikes.get(host, 0) + 1
            else:
                self.strikes[host] = 0
            if self.strikes.get(host, 0) >= self.strikes_to_flag:
                flagged.append(host)
        return flagged


@dataclasses.dataclass(frozen=True)
class RemeshPlan:
    data: int
    model: int
    dropped_devices: int

    @property
    def devices(self) -> int:
        return self.data * self.model


def plan_elastic_remesh(surviving_devices: int, model_axis: int
                        ) -> RemeshPlan:
    """Largest (data, model) mesh from the survivors, model axis preserved.

    Model sharding cannot shrink without resharding every weight, so the
    model axis is kept and the data axis becomes
    floor(survivors / model_axis) — any remainder idles until replacement
    capacity arrives.
    """
    if surviving_devices < model_axis:
        raise RuntimeError(
            f"cannot re-mesh: {surviving_devices} survivors < model axis "
            f"{model_axis}; training must wait for replacements")
    data = surviving_devices // model_axis
    return RemeshPlan(data, model_axis,
                      surviving_devices - data * model_axis)


@dataclasses.dataclass
class ResilienceReport:
    steps_completed: int
    failures_survived: int
    restores: int
    final_step: int


def run_resilient_loop(step_fn: Callable[[int], None],
                       save_fn: Callable[[int], None],
                       restore_fn: Callable[[], int],
                       total_steps: int,
                       checkpoint_every: int = 50,
                       max_failures: int = 10) -> ResilienceReport:
    """Supervisor loop: survives step_fn raising by restoring and retrying.

    ``step_fn(step)`` runs one training step (raising on simulated/real
    failure); ``restore_fn()`` returns the step to resume from.
    """
    failures = restores = 0
    step = restore_fn()
    start = step
    while step < total_steps:
        try:
            step_fn(step)
            step += 1
            if step % checkpoint_every == 0:
                save_fn(step)
        except Exception:  # noqa: BLE001 — any step failure triggers recovery
            failures += 1
            if failures > max_failures:
                raise
            step = restore_fn()
            restores += 1
    return ResilienceReport(step - start, failures, restores, step)

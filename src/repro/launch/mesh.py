"""Production mesh construction (deliverable e).

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state.  Single pod: 16x16 = 256
chips, axes (data, model).  Multi-pod: 2x16x16 = 512 chips with a leading
'pod' axis (outer data parallelism; gradient all-reduce crosses the pod
boundary, which the multi-pod dry-run proves out).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1):
    """Whatever this host has — used by examples and integration tests."""
    n = len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))

"""Serving driver: batched prefill + decode with KV caches.

Runs a reduced-config model end to end on CPU: builds a request batch,
prefills, then greedy-decodes N tokens per request.  The same prefill/
decode step functions are what dryrun.py lowers at production shapes.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model_zoo as zoo


@dataclasses.dataclass
class ServeResult:
    tokens: np.ndarray            # (B, prompt+gen)
    prefill_s: float
    decode_s: float
    tokens_per_s: float


def serve(arch: str, smoke: bool = True, batch: int = 4,
          prompt_len: int = 32, gen: int = 16, seed: int = 0,
          greedy: bool = True, temperature: float = 1.0) -> ServeResult:
    cfg = get_config(arch, smoke=smoke)
    key = jax.random.PRNGKey(seed)
    params = zoo.init_params(cfg, key)
    max_len = prompt_len + gen
    caches = zoo.init_caches(cfg, batch, max_len, jnp.dtype(cfg.dtype))

    prompts = jax.random.randint(jax.random.fold_in(key, 1),
                                 (batch, prompt_len), 0, cfg.vocab_size)
    b = {"tokens": prompts}
    if cfg.family == "audio":
        b["frames"] = jnp.zeros((batch, cfg.encoder_seq,
                                 zoo.WHISPER_FRAME_FEAT),
                                jnp.dtype(cfg.dtype))
    if cfg.family == "vlm":
        b["patches"] = jnp.zeros((batch, cfg.num_image_tokens,
                                  cfg.vision_embed_dim), jnp.dtype(cfg.dtype))

    decode = jax.jit(
        lambda p, t, i, s: zoo.decode_fn(p, t, i, cfg, s))

    t0 = time.time()
    logits, state = zoo.prefill_fn(params, b, cfg, caches)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    out: List[jnp.ndarray] = []
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out.append(tok)
    t1 = time.time()
    for i in range(gen - 1):
        logits, state = decode(params, tok, jnp.int32(prompt_len + i), state)
        if greedy:
            tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        else:
            tok = jax.random.categorical(
                jax.random.fold_in(key, 100 + i),
                logits[:, -1] / temperature)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t1
    seq = np.asarray(jnp.concatenate([prompts] + out, axis=1))
    return ServeResult(seq, t_prefill, t_decode,
                       batch * gen / max(t_decode, 1e-9))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    r = serve(args.arch, args.smoke, args.batch, args.prompt_len, args.gen)
    print(f"prefill {r.prefill_s*1e3:.1f} ms, decode {r.decode_s*1e3:.1f} ms"
          f" ({r.tokens_per_s:.1f} tok/s), output shape {r.tokens.shape}")


if __name__ == "__main__":
    main()

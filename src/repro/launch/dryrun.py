import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST precede every other import (jax locks the device count on first
# init) — deliverable e, MULTI-POD DRY-RUN step 0.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds abstract inputs (ShapeDtypeStruct only — nothing
is allocated), jits the right step function with explicit in_shardings,
compiles it, and records:

  * memory_analysis()        -> bytes per device (proves it fits)
  * cost_analysis()          -> HLO FLOPs / bytes for §Roofline
  * compiled HLO text scan   -> per-collective-kind byte volumes

Results go to experiments/dryrun/<arch>__<shape>__<mesh>.json and a summary
line per cell is printed.  Idempotent: existing JSONs are skipped unless
--force.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b \
      --shape train_4k --mesh single --force
"""
import argparse
import functools
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, cell_is_supported, get_config, list_archs
from repro.launch.mesh import make_production_mesh
from repro.models import model_zoo as zoo
from repro.models import moe as moe_mod
from repro.optim import optimizer as opt
from repro.parallel import sharding as shd

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in optimized HLO."""
    out = {k: 0 for k in COLLECTIVES}
    counts = {k: 0 for k in COLLECTIVES}
    shape_re = re.compile(r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\]")
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = shape_re.search(stripped)
        if not m:
            continue
        op = None
        for k in COLLECTIVES:
            if re.search(rf"\b{k}(-start|-done)?\(", stripped):
                op = k
                break
        if op is None or "-done(" in stripped:
            continue
        dt, dims = m.group(1), m.group(2)
        nbytes = DTYPE_BYTES.get(dt, 4)
        for d in dims.split(","):
            if d:
                nbytes *= int(d)
        out[op] += nbytes
        counts[op] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


def convert_bytes(hlo_text: str) -> int:
    """Output bytes of dtype-convert ops.

    XLA:CPU emulates bf16 dots by converting operands to f32 — traffic a
    real TPU (native bf16 MXU) never sees.  The roofline reports a
    TPU-adjusted memory term that subtracts 1.5x these bytes (f32 output +
    half-size bf16 input) as the documented upper/lower bracket.
    """
    total = 0
    pat = re.compile(r"=\s*([a-z0-9]+)\[([0-9,]*)\][^=]*\bconvert\(")
    for line in hlo_text.splitlines():
        m = pat.search(line)
        if not m:
            continue
        nb = DTYPE_BYTES.get(m.group(1), 4)
        for d in m.group(2).split(","):
            if d:
                nb *= int(d)
        total += nb
    return total


def _abstract_params(cfg):
    return zoo.init_params(cfg, jax.random.PRNGKey(0), abstract=True)


def _abstract_opt_state(abs_params):
    f32 = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), abs_params)
    return opt.AdamState(jax.ShapeDtypeStruct((), jnp.int32), f32,
                         jax.tree.map(lambda s: s, f32))


def build_step(cfg, shape, mesh):
    """Returns (fn, abstract_args, in_shardings, donate_argnums)."""
    dist = moe_mod.DistCtx(mesh=mesh, data_axes=shd.data_axes(mesh))
    abs_params = _abstract_params(cfg)
    specs = zoo.param_specs(cfg)
    # MoE giants need FSDP to fit; dense archs keep pure TP (DESIGN.md §5)
    rules = shd.FSDP_RULES if cfg.moe is not None else None
    p_shard = shd.param_shardings(specs, mesh, abs_params, rules)
    adam_cfg = opt.AdamWConfig()

    if shape.kind == "train":
        abs_opt = _abstract_opt_state(abs_params)
        # ZeRO-1: fp32 moments additionally sharded over the data axes.
        m_shard = shd.zero1_shardings(specs, mesh, abs_params, rules)
        o_shard = opt.AdamState(shd.replicated(mesh), m_shard, m_shard)
        batch = zoo.input_specs(cfg, shape)
        b_shard = {k: shd.batch_pspec(mesh, v) for k, v in batch.items()}

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(zoo.loss_fn)(
                params, batch, cfg, dist=dist)
            params, opt_state, metrics = opt.apply(adam_cfg, params,
                                                   opt_state, grads)
            return params, opt_state, {"loss": loss, **metrics}

        return (train_step, (abs_params, abs_opt, batch),
                (p_shard, o_shard, b_shard), (0, 1))

    if shape.kind == "prefill":
        batch = zoo.input_specs(cfg, shape)
        b_shard = {k: shd.batch_pspec(mesh, v) for k, v in batch.items()}

        def prefill_step(params, batch):
            caches = zoo.init_caches(cfg, shape.global_batch, shape.seq_len,
                                     jnp.dtype(cfg.dtype))
            logits, state = zoo.prefill_fn(params, batch, cfg, caches,
                                           dist=dist)
            return logits, state

        return prefill_step, (abs_params, batch), (p_shard, b_shard), ()

    # decode
    inputs = zoo.input_specs(cfg, shape)
    state = zoo.cache_specs(cfg, shape)
    c_shard = shd.cache_shardings(state, mesh, shape.global_batch)
    tok_shard = shd.batch_pspec(mesh, inputs["token"]) \
        if shape.global_batch % mesh.shape[shd.data_axes(mesh)[0]] == 0 \
        else shd.replicated(mesh)
    i_shard = shd.replicated(mesh)

    def serve_step(params, state, token, index):
        logits, new_state = zoo.decode_fn(params, token, index, cfg, state,
                                          dist=dist)
        return logits, new_state

    return (serve_step, (abs_params, state, inputs["token"],
                         inputs["index"]),
            (p_shard, c_shard, tok_shard, i_shard), (1,))


def run_cell(arch: str, shape_name: str, mesh_kind: str, force: bool = False
             ) -> dict:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{arch}__{shape_name}__{mesh_kind}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_is_supported(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind}
    if not ok:
        rec.update(status="skipped", reason=why)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    try:
        fn, args, in_sh, donate = build_step(cfg, shape, mesh)
        with mesh:
            jitted = jax.jit(fn, in_shardings=in_sh, donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        n_dev = mesh.devices.size
        mem_rec = {}
        if mem is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes"):
                mem_rec[k] = getattr(mem, k, None)
        rec.update(
            status="ok",
            devices=n_dev,
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            flops=cost.get("flops") if cost else None,
            bytes_accessed=cost.get("bytes accessed") if cost else None,
            memory=mem_rec,
            collectives=coll,
            hlo_size=len(hlo),
        )
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default=None, choices=(None, "single", "multi"))
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    archs = [args.arch] if args.arch else list(list_archs())
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [args.mesh] if args.mesh else ["single", "multi"]
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                rec = run_cell(arch, shape, mesh_kind, args.force)
                flops = rec.get("flops")
                print(f"{arch:24s} {shape:12s} {mesh_kind:6s} "
                      f"{rec['status']:7s} "
                      f"flops={flops if flops else '-':>14} "
                      f"coll={rec.get('collectives', {}).get('total_bytes', '-')}",
                      flush=True)


if __name__ == "__main__":
    main()

"""Runnable training driver (deliverable b's end-to-end path).

Trains any registered arch (``--smoke`` for the reduced config on CPU) on
the deterministic synthetic pipeline, with AdamW, checkpoint/restart,
straggler tracking, and optional photonic-numerics QAT (``--numerics
photonic_heana``).  The same step function lowers on the production mesh
in dryrun.py — this driver is the real-execution twin.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
      --steps 50 --batch 8 --seq 64
  PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m --smoke \
      --steps 30 --numerics photonic_heana
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpoint as ckpt
from repro.configs import get_config
from repro.core.types import Backend, PhotonicConfig
from repro.data.pipeline import DataConfig, make_source
from repro.models import model_zoo as zoo
from repro.models import moe as moe_mod
from repro.models.layers import PhotonicCtx
from repro.optim import optimizer as opt
from repro.runtime.fault_tolerance import StragglerPolicy

NUMERICS = {
    "exact": None,
    "int8": PhotonicConfig(backend=Backend.INT_QUANT, bits=8,
                           noise_enabled=False),
    "photonic_heana": PhotonicConfig(backend=Backend.HEANA, bits=8,
                                     adc_bits=12, dpe_size=128,
                                     noise_enabled=False),
    "photonic_amw": PhotonicConfig(backend=Backend.AMW, bits=8, adc_bits=12,
                                   dpe_size=64, noise_enabled=False),
}


@dataclasses.dataclass
class TrainResult:
    steps: int
    first_loss: float
    final_loss: float
    tokens_per_s: float
    ckpt_dir: Optional[str]


def train(arch: str, smoke: bool = True, steps: int = 50, batch: int = 8,
          seq: int = 64, lr: float = 1e-3, numerics: str = "exact",
          ckpt_dir: Optional[str] = None, ckpt_every: int = 25,
          resume: bool = False, log_every: int = 10,
          seed: int = 0, total_steps: Optional[int] = None) -> TrainResult:
    """``total_steps`` fixes the LR-schedule horizon independently of how
    many steps this invocation runs — required for exact resume semantics
    (a restarted run must see the same schedule)."""
    cfg = get_config(arch, smoke=smoke)
    horizon = total_steps or steps
    adam = opt.AdamWConfig(lr=lr, warmup_steps=max(2, horizon // 20),
                           total_steps=horizon)
    pcfg = NUMERICS[numerics]
    ctx = PhotonicCtx(cfg=pcfg, impl="ref") if pcfg else PhotonicCtx()

    params = zoo.init_params(cfg, jax.random.PRNGKey(seed))
    state = opt.init(params)
    start_step = 0
    if resume and ckpt_dir and ckpt.latest_step(ckpt_dir) is not None:
        (params, state), manifest = ckpt.restore(
            ckpt_dir, (params, state))
        start_step = manifest["step"]
        print(f"resumed from step {start_step}")

    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                          global_batch=batch, seed=seed)
    source = make_source(data_cfg)

    @jax.jit
    def train_step(params, state, tokens, targets):
        def loss_fn(p):
            return zoo.loss_fn(p, {"tokens": tokens, "targets": targets},
                               cfg, ctx=ctx, dist=moe_mod.LOCAL)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, state, metrics = opt.apply(adam, params, state, grads)
        return params, state, loss, metrics

    straggler = StragglerPolicy()
    first_loss = final_loss = float("nan")
    tokens_total = 0
    t0 = time.time()
    for step in range(start_step, steps):
        b = source.batch(step)
        ts = time.time()
        extra = {}
        if cfg.family == "audio":
            extra["frames"] = jnp.zeros(
                (batch, cfg.encoder_seq, zoo.WHISPER_FRAME_FEAT),
                jnp.dtype(cfg.dtype))
        if cfg.family == "vlm":
            extra["patches"] = jnp.zeros(
                (batch, cfg.num_image_tokens, cfg.vision_embed_dim),
                jnp.dtype(cfg.dtype))
        if extra:
            loss, grads = jax.value_and_grad(zoo.loss_fn)(
                params, {"tokens": jnp.asarray(b["tokens"]),
                         "targets": jnp.asarray(b["targets"]), **extra},
                cfg, ctx=ctx)
            params, state, metrics = opt.apply(adam, params, state, grads)
        else:
            params, state, loss, metrics = train_step(
                params, state, jnp.asarray(b["tokens"]),
                jnp.asarray(b["targets"]))
        loss = float(loss)
        straggler.record("host0", time.time() - ts)
        straggler.update_strikes()
        tokens_total += batch * seq
        if step == start_step:
            first_loss = loss
        final_loss = loss
        if step % log_every == 0 or step == steps - 1:
            print(f"step {step:5d} loss {loss:8.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.3f}", flush=True)
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            ckpt.save(ckpt_dir, step + 1, (params, state),
                      extra={"loss": loss})
            ckpt.retain(ckpt_dir, keep_last=3)
    dt = time.time() - t0
    return TrainResult(steps - start_step, first_loss, final_loss,
                       tokens_total / max(dt, 1e-9), ckpt_dir)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--numerics", default="exact", choices=list(NUMERICS))
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()
    res = train(args.arch, args.smoke, args.steps, args.batch, args.seq,
                args.lr, args.numerics, args.ckpt_dir, resume=args.resume)
    print(f"done: loss {res.first_loss:.4f} -> {res.final_loss:.4f} "
          f"({res.tokens_per_s:.0f} tok/s)")


if __name__ == "__main__":
    main()

"""Deterministic, restart-safe token data pipeline.

Two sources:
  * SyntheticLM — procedurally generated token streams (Zipfian unigrams
    with a repeated-motif structure so models can actually learn), fully
    determined by (seed, step): any host can reproduce any batch, which is
    what makes checkpoint-restart and elastic rescaling exact.
  * FileShards — newline-delimited uint16/uint32 token shards on disk,
    sharded per host, with a resumable cursor.

Per-host sharding: each host materializes only its slice of the global
batch (``host_slice``), and the launcher reassembles the global array with
jax.make_array_from_process_local_data (single-host: trivial).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    source: str = "synthetic"          # synthetic | file
    path: Optional[str] = None
    motif_len: int = 16                # synthetic structure
    motif_count: int = 64


class SyntheticLM:
    """Batch b at step s is a pure function of (seed, s, b) — stateless."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        root = np.random.default_rng(cfg.seed)
        # Zipfian unigram table + a bank of motifs the stream repeats.
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        self.unigram = (1.0 / ranks) / np.sum(1.0 / ranks)
        self.motifs = root.integers(
            0, cfg.vocab_size, size=(cfg.motif_count, cfg.motif_len),
            dtype=np.int64)

    def batch(self, step: int, host_index: int = 0,
              host_count: int = 1) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        per_host = cfg.global_batch // host_count
        rng = np.random.default_rng(
            (cfg.seed, step, host_index))
        length = cfg.seq_len + 1
        rows = np.empty((per_host, length), dtype=np.int64)
        for r in range(per_host):
            stream = rng.choice(cfg.vocab_size, size=length,
                                p=self.unigram)
            # inject motifs: predictable structure for the model to learn
            n_inj = length // (cfg.motif_len * 2)
            starts = rng.integers(0, max(1, length - cfg.motif_len),
                                  size=n_inj)
            for st in starts:
                m = self.motifs[rng.integers(0, cfg.motif_count)]
                stream[st:st + cfg.motif_len] = m[:length - st][:cfg.motif_len]
            rows[r] = stream
        return {"tokens": rows[:, :-1].astype(np.int32),
                "targets": rows[:, 1:].astype(np.int32)}


class FileShards:
    """Token shards: <path>/shard_*.npy (1-D int arrays), resumable."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.files = sorted(
            os.path.join(cfg.path, f) for f in os.listdir(cfg.path)
            if f.startswith("shard_") and f.endswith(".npy"))
        if not self.files:
            raise FileNotFoundError(f"no shard_*.npy under {cfg.path}")

    def batch(self, step: int, host_index: int = 0,
              host_count: int = 1) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        per_host = cfg.global_batch // host_count
        length = cfg.seq_len + 1
        shard = np.load(self.files[(step * host_count + host_index)
                                   % len(self.files)], mmap_mode="r")
        need = per_host * length
        start = (step * need) % max(1, len(shard) - need)
        flat = np.asarray(shard[start:start + need], dtype=np.int64)
        if len(flat) < need:
            flat = np.pad(flat, (0, need - len(flat)))
        rows = flat.reshape(per_host, length)
        return {"tokens": rows[:, :-1].astype(np.int32),
                "targets": rows[:, 1:].astype(np.int32)}


def make_source(cfg: DataConfig):
    return FileShards(cfg) if cfg.source == "file" else SyntheticLM(cfg)


def iterate(cfg: DataConfig, start_step: int = 0, host_index: int = 0,
            host_count: int = 1) -> Iterator[Dict[str, np.ndarray]]:
    src = make_source(cfg)
    step = start_step
    while True:
        yield src.batch(step, host_index, host_count)
        step += 1

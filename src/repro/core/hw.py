"""Unified hardware operating point — ONE source of truth from the
scalability solver down to the executed kernels.

HEANA's headline results are *equal-area* FPS and FPS/W comparisons in
which the achievable DPE size N, the detection-noise level, and every
per-event energy are all functions of one operating point: (backend,
bit-precision B, data rate DR, DPU organization).  Before this module the
repo's executed path took those knobs independently — ``PhotonicConfig``
(kernel numerics), ``AcceleratorConfig`` (scheduler/perf model) and the
noise/energy constants could silently disagree with the analytic model
they claim to reproduce.

``OperatingPoint`` closes that: given (backend, dataflow, bits, DR) it
derives everything downstream from the existing solvers —

  * DPE size N from ``core.scalability.max_dpe_size`` (Eqs. 1-3, Fig. 9);
  * the per-photodiode optical power from the link budget (Eq. 3);
  * the detection sigma from ``core.noise.relative_noise_sigma``;
  * per-event energies from ``core.energy`` (Table 3);

and fans out a *coherent* pair of downstream configs via
``kernel_config()`` (a ``PhotonicConfig`` for the Pallas kernels) and
``accelerator_config()`` (an ``AcceleratorConfig`` for the scheduler /
perf model).  ``repro.exec.scheduler`` embeds the operating point in its
plans (plan v4) and ``repro.exec.executor`` refuses kernel configs that
disagree with a plan's hardware (``check_kernel_plan_coherence``), so the
executed system and the analytic model cannot drift apart.

Executed-trace energy: ``trace_energy(plan)`` turns a CnnPlan's executed
layer list (batch folded into the GEMM rows, per-layer dataflows, grouped
depthwise counts) into per-layer ``EnergyBreakdown``s and whole-network
FPS / FPS/W — charged by the SAME ``core.perf_model.gemm_cost`` event
accounting the analytic figures use, plus the static-power share over the
executed wall-clock.  Depthwise layers are charged on the paper's grouped
accounting (count x (C, k*k, 1) GEMMs): the executor's fused
block-diagonal GEMM is a host-simulation device, not extra photonic work
(the fused matrix is mostly structural zeros).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.core import energy as en
from repro.core import noise as noise_mod
from repro.core import scalability
from repro.core.types import (Backend, Dataflow, NETWORK_PENALTY_DB,
                              OpticalParams, PhotonicConfig)

#: Backends with a photonic operating point (EXACT / INT_QUANT bypass the
#: photonic pipeline entirely — no link budget, no detector, no energy).
PHOTONIC_BACKENDS = ("heana", "amw", "maw", "amw_bpca", "maw_bpca")


def _base_backend(backend: str) -> str:
    return backend.replace("_bpca", "")


@dataclasses.dataclass(frozen=True)
class EventEnergies:
    """Per-event energies (J) and standing powers (W) at one operating
    point — the Table 3 constants specialized to (backend, DR, N, DPUs)."""
    adc_j: float              # one ADC conversion
    dac_j: float              # one operand symbol entering the analog domain
    edram_j: float            # one unified-buffer element access
    reduction_j: float        # one reduction-network pass
    to_tune_j: float          # one thermo-optic ring actuation (AMW/MAW)
    laser_w: float            # comb laser electrical power, one DPU
    static_w: float           # always-on peripherals, whole accelerator


@dataclasses.dataclass(frozen=True)
class OperatingPoint:
    """One hardware operating point: (backend, dataflow, bits, DR) plus the
    solver-derived DPE geometry.

    Construct via :meth:`design` (N solved from the scalability analysis,
    paper Fig. 9) or :meth:`equal_area` (paper Table 2's area-matched
    (N, DPU-count) pairs at B=4).  The raw constructor accepts explicit
    ``dpe_size``/``n_dpus`` overrides for expert use; ``None`` means
    "derive" (the normal case).

    Everything downstream hangs off this object: ``kernel_config()`` and
    ``accelerator_config()`` produce the coherent config pair, and
    ``noise_sigma()`` / ``event_energies()`` expose the derived physics so
    reports can show *why* the numbers are what they are.
    """
    backend: str = "heana"
    dataflow: Dataflow = Dataflow.OS
    bits: int = 4                      # operand precision B (paper: 4)
    data_rate_gsps: float = 1.0        # DR
    dpe_size: Optional[int] = None     # N; None => solve from (B, DR)
    n_dpus: Optional[int] = None       # None => 1 (design) / Table 2
    adc_bits: int = 8
    noise_enabled: bool = True
    optics: OpticalParams = dataclasses.field(default_factory=OpticalParams)

    def __post_init__(self):
        if _base_backend(self.backend) not in NETWORK_PENALTY_DB:
            raise ValueError(
                f"unknown photonic backend {self.backend!r} — expected one "
                f"of {PHOTONIC_BACKENDS} (EXACT/INT_QUANT have no "
                f"operating point; build a PhotonicConfig directly)")
        if self.dpe_size is None:
            n = scalability.max_dpe_size(self.backend, self.bits,
                                         self.data_rate_gsps, self.optics)
            if n < 1:
                raise ValueError(
                    f"{self.bits}-bit operation at "
                    f"{self.data_rate_gsps} GS/s is optically infeasible "
                    f"for {self.backend!r} (link budget cannot deliver "
                    f"the required receiver power even at N=1 — the "
                    f"paper Fig. 9 RIN cliff)")
            object.__setattr__(self, "dpe_size", n)
        elif self.dpe_size < 1:
            raise ValueError(f"dpe_size must be >= 1, got {self.dpe_size}")
        if self.n_dpus is None:
            object.__setattr__(self, "n_dpus", 1)
        elif self.n_dpus < 1:
            raise ValueError(f"n_dpus must be >= 1, got {self.n_dpus}")

    # -- constructors --------------------------------------------------------
    @classmethod
    def design(cls, backend: str, dataflow: Dataflow = Dataflow.OS,
               bits: int = 4, data_rate_gsps: float = 1.0,
               n_dpus: int = 1, **kw) -> "OperatingPoint":
        """The Fig. 9 design point: N solved from the scalability analysis
        for (backend, bits, DR)."""
        return cls(backend=backend, dataflow=dataflow, bits=bits,
                   data_rate_gsps=data_rate_gsps, n_dpus=n_dpus, **kw)

    @classmethod
    def equal_area(cls, backend: str, dataflow: Dataflow = Dataflow.OS,
                   data_rate_gsps: float = 1.0, **kw) -> "OperatingPoint":
        """Paper Table 2: the area-matched system evaluation points at
        B=4 — (N, DPU count) pairs normalized to HEANA(N=83, 50 DPUs).

        N comes from the published table, not the solver (the solver
        reproduces 8 of the 9 anchors exactly; Table 2's MAW@5GS/s entry
        is the documented off-by-one — the table wins here so the
        equal-area figures match the paper's).
        """
        n, count = scalability.table2_dpu_config(backend, data_rate_gsps)
        return cls(backend=backend, dataflow=dataflow, bits=4,
                   data_rate_gsps=data_rate_gsps, dpe_size=n,
                   n_dpus=count, **kw)

    # -- derived physics -----------------------------------------------------
    @property
    def n(self) -> int:
        """DPE size N (wavelengths per DPE; M = N DPEs per DPU)."""
        return self.dpe_size

    def pd_power_dbm(self) -> float:
        """Per-wavelength optical power at the photodiode: the Eq. 3 link
        budget evaluated at this point's N (M = N, paper's assumption)."""
        key = _base_backend(self.backend)
        return scalability.output_power_dbm(
            self.n, self.n, NETWORK_PENALTY_DB[key], self.optics,
            scalability.obl_passes_for(self.backend))

    def noise_sigma(self) -> float:
        """Relative detection-noise sigma of one BPD integration event at
        this operating point (== ``noise.relative_noise_sigma`` at the
        link-budget power — the same sigma the kernels inject)."""
        return noise_mod.relative_noise_sigma(
            self.pd_power_dbm(), self.data_rate_gsps, self.optics)

    def enob(self) -> float:
        """Effective number of bits actually resolvable at this point."""
        return noise_mod.enob(self.pd_power_dbm(), self.data_rate_gsps,
                              self.optics)

    def event_energies(self) -> EventEnergies:
        """Per-event energies / standing powers (core.energy, Table 3)."""
        return EventEnergies(
            adc_j=en.E_ADC_CONV,
            dac_j=en.dac_energy_per_symbol(self.backend,
                                           self.data_rate_gsps),
            edram_j=en.E_EDRAM_ACCESS,
            reduction_j=en.E_REDUCTION_PASS,
            to_tune_j=en.E_TO_TUNE_PER_RING,
            laser_w=en.laser_power_w(self.n, self.optics.p_laser_dbm),
            static_w=en.static_power_w(self.n_dpus),
        )

    # -- coherent downstream configs -----------------------------------------
    def kernel_config(self, **overrides) -> PhotonicConfig:
        """The numerics config the Pallas kernels consume, derived from
        this point — same backend, bits, N, DR, dataflow and optics, so
        the injected noise sigma IS ``noise_sigma()``.

        ``overrides`` replace fields on the derived config (e.g.
        ``noise_enabled=False`` for deterministic runs, ``adc_bits=...``).
        Overriding the hardware identity (backend / bits / dpe_size /
        data_rate_gsps) defeats the point of the operating point and will
        be rejected by the executor's coherence check against a plan
        carrying this point.
        """
        cfg = PhotonicConfig(
            backend=Backend(self.backend), bits=self.bits,
            adc_bits=self.adc_bits, dpe_size=self.n,
            data_rate_gsps=self.data_rate_gsps, dataflow=self.dataflow,
            noise_enabled=self.noise_enabled, optics=self.optics)
        return dataclasses.replace(cfg, **overrides) if overrides else cfg

    def accelerator_config(self):
        """The scheduler / perf-model AcceleratorConfig for this point
        (``repro.core.perf_model.AcceleratorConfig``; imported lazily —
        perf_model pulls in the model zoo)."""
        from repro.core import perf_model as pm
        return pm.AcceleratorConfig(
            backend=self.backend, dataflow=self.dataflow,
            data_rate_gsps=self.data_rate_gsps, n=self.n, m=self.n,
            n_dpus=self.n_dpus)

    def describe(self) -> dict:
        """JSON-safe summary (reports / experiment provenance)."""
        return {
            "backend": self.backend,
            "dataflow": self.dataflow.value,
            "bits": self.bits,
            "data_rate_gsps": self.data_rate_gsps,
            "dpe_size": self.n,
            "n_dpus": self.n_dpus,
            "pd_power_dbm": self.pd_power_dbm(),
            "noise_sigma_rel": self.noise_sigma(),
            "enob": self.enob(),
            "static_w": self.event_energies().static_w,
        }


# ---------------------------------------------------------------------------
# Kernel-config <-> plan coherence (consumed by repro.exec.executor)
# ---------------------------------------------------------------------------
#: Kernel backends that bypass the photonic pipeline — no geometry to check.
_NON_PHOTONIC = (Backend.EXACT, Backend.INT_QUANT)


def kernel_plan_mismatches(cfg: PhotonicConfig, acc,
                           op: Optional[OperatingPoint] = None
                           ) -> List[str]:
    """Field-by-field disagreement between a kernel config and the
    hardware a plan was scheduled for.  Empty list == coherent.

    ``acc`` is the plan's AcceleratorConfig; ``op`` the plan's embedded
    OperatingPoint when it has one (plan v4).  Without an operating point
    only the geometry the AcceleratorConfig carries (backend organization,
    DPE size N, data rate) is checkable — ``bits`` lives on the operating
    point, so legacy plans cannot pin it.
    """
    if cfg.backend in _NON_PHOTONIC:
        return []
    probs: List[str] = []
    if cfg.backend.value != acc.backend:
        probs.append(f"backend: kernel cfg simulates "
                     f"{cfg.backend.value!r} but the plan was scheduled "
                     f"for {acc.backend!r}")
    if cfg.dpe_size != acc.n:
        probs.append(f"DPE size: kernel cfg folds K in chunks of "
                     f"N={cfg.dpe_size} but the plan's hardware has "
                     f"N={acc.n}")
    if cfg.data_rate_gsps != acc.data_rate_gsps:
        probs.append(f"data rate: kernel cfg at {cfg.data_rate_gsps} GS/s "
                     f"vs the plan's {acc.data_rate_gsps} GS/s")
    if op is not None:
        if cfg.bits != op.bits:
            probs.append(f"bits: kernel cfg quantizes to B={cfg.bits} but "
                         f"the operating point was solved for B={op.bits} "
                         f"(its N={op.n} is only achievable at that "
                         f"precision)")
        if cfg.optics != op.optics:
            probs.append("optics: kernel cfg and operating point carry "
                         "different OpticalParams — their link budgets "
                         "(and noise sigmas) disagree")
        if cfg.pd_power_dbm is not None and \
                cfg.pd_power_dbm != op.pd_power_dbm():
            probs.append(
                f"PD power: kernel cfg hand-sets "
                f"{cfg.pd_power_dbm:.3f} dBm at the photodiode but the "
                f"operating point's link budget delivers "
                f"{op.pd_power_dbm():.3f} dBm — the injected noise "
                f"sigma would disagree with the solved precision "
                f"(leave pd_power_dbm=None to derive it)")
    return probs


def check_kernel_plan_coherence(cfg: PhotonicConfig, plan) -> None:
    """Raise ValueError when a kernel config disagrees with ``plan``'s
    hardware (the executor calls this in ``_validate``).

    ``plan`` is duck-typed: anything with ``.acc`` and (optionally)
    ``.op`` — i.e. a scheduler CnnPlan.
    """
    probs = kernel_plan_mismatches(cfg, plan.acc,
                                   getattr(plan, "op", None))
    if probs:
        fix = ("derive both configs from one OperatingPoint — "
               "op.kernel_config() for the kernels and "
               "schedule_cnn(..., op) (or plan_for_network(params, op)) "
               "for the plan — instead of setting the knobs by hand")
        raise ValueError(
            "kernel config and plan describe DIFFERENT hardware — the "
            "executed numerics would silently diverge from the modeled "
            "latency/energy:\n  - " + "\n  - ".join(probs) + f"\nFix: {fix}")


# ---------------------------------------------------------------------------
# Executed-trace energy accounting (consumed by repro.exec.executor/report)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TraceEnergy:
    """Whole-network energy/FPS accounting of one executed plan.

    ``per_layer_j`` follows the plan's layer order (count included, no
    static share); ``breakdown`` holds the component totals including the
    static-power share over the executed wall-clock.  FPS and FPS/W are
    the executed-trace equivalents of ``perf_model.InferenceResult`` — by
    construction they agree with ``cnn_inference`` run at the same
    per-layer dataflows (pinned by tests/test_energy_trace.py).
    """
    batch: int
    latency_s: float
    per_layer_j: Tuple[float, ...]
    breakdown: en.EnergyBreakdown

    @property
    def energy_j(self) -> float:
        return self.breakdown.total

    @property
    def fps(self) -> float:
        return self.batch / self.latency_s

    @property
    def watts(self) -> float:
        return self.breakdown.total / self.latency_s

    @property
    def fps_per_watt(self) -> float:
        return self.fps / self.watts

    @property
    def j_per_image(self) -> float:
        return self.breakdown.total / self.batch


def trace_energy(plan, optics: Optional[OpticalParams] = None
                 ) -> TraceEnergy:
    """Energy/FPS of what a plan's executor run actually does.

    Walks the plan's executed layer list — batch already folded into the
    GEMM rows, the auto-scheduled per-layer dataflow, the paper's grouped
    depthwise accounting — and charges each layer with the SAME
    ``perf_model.gemm_cost`` event accounting the analytic figures use,
    then adds the static-power share over the summed wall-clock.  One
    accounting path for modeled and executed numbers: coherence by
    construction.

    Optics: charged at the plan's operating-point optics (default
    OpticalParams for legacy plans) — the same optics ``schedule_cnn``
    passes to ``cnn_inference`` for the plan's ``result``, so executed
    and modeled totals agree for non-default optics too.  Note the
    cached per-layer ``LayerPlan.energy_j`` is always a default-optics
    figure (the plan cache keys on the accelerator config alone); only
    the laser term differs.
    """
    from repro.core import perf_model as pm
    optics = optics or (plan.op.optics if getattr(plan, "op", None)
                        else None)
    # THE shared accounting path (perf_model.layer_costs — the same one
    # cnn_inference sums): plan.layers carry batch-folded rows, so
    # batch=1 here; the per-layer dataflows are the plan's.
    costs = pm.layer_costs(plan.layers, plan.acc, batch=1,
                           dataflows=[p.dataflow for p in plan.layers],
                           optics=optics)
    total_t = 0.0
    total = en.EnergyBreakdown()
    per_layer: List[float] = []
    for cost in costs:
        total_t += cost.latency_s
        for f in pm._DYNAMIC_ENERGY_FIELDS:
            setattr(total, f, getattr(total, f) + getattr(cost.energy, f))
        per_layer.append(cost.energy.total)
    total.static = en.static_power_w(plan.acc.n_dpus) * total_t
    return TraceEnergy(batch=plan.batch, latency_s=total_t,
                       per_layer_j=tuple(per_layer), breakdown=total)

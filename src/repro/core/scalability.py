"""Scalability analysis — paper Section 5 (Eqs. 1-3, Fig. 9, Table 2).

Given an operand bit-precision B and data rate DR, the achievable DPE size N
is the largest N for which the optical power that survives the link budget
(Eq. 3) still meets the receiver sensitivity P_PD-opt required for B bits at
DR (Eqs. 1-2, inverted in core.noise).

The link budget is evaluated with M = N (paper's assumption) and differs
between the DPU organizations only through the network penalty P_penalty
(Table 1: HEANA 1.8 dB, MAW 4.8 dB, AMW 5.8 dB) — the hitless TAOM
arrangement is what buys HEANA its much smaller penalty and hence its much
larger N.

Calibration note (DESIGN.md §6.4): Table 1 omits d_MRR and P_SMF-att.  With
d_MRR = 0.02 mm, P_SMF-att = 0.14 dB, and a single out-of-band-loss pass for
HEANA (its hitless arrangement routes each wavelength through the filter
array once, vs the MRM-array + weight-bank double pass of AMW/MAW) the
solver reproduces 8 of the paper's 9 Fig.9/Table 2 anchors exactly at B=4
(HEANA 83/42/30, AMW 36/17/12, MAW 43/[22 vs 21]/15); these values are held
fixed everywhere else.
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, Tuple

from repro.core import noise
from repro.core.types import NETWORK_PENALTY_DB, OpticalParams

MAX_N = 4096


def output_power_dbm(n: int, m: int, penalty_db: float,
                     optics: OpticalParams, obl_passes: int = 2) -> float:
    """Optical power reaching one photodiode — paper Eq. 3.

    ``obl_passes`` is the number of times a wavelength suffers the
    out-of-band loss of the other N-1 rings: 2 for AMW/MAW (MRM input array
    then MRR weight bank), 1 for HEANA's hitless arrangement (each
    wavelength crosses the mono-wavelength filter array once).
    """
    if n < 1 or m < 1:
        raise ValueError("N and M must be >= 1")
    p = optics.p_laser_dbm
    p -= optics.p_smf_att_db
    p -= optics.p_ec_il_db
    p -= optics.p_si_att_db_mm * n * optics.d_mrr_mm
    p -= optics.p_mrm_il_db
    p -= optics.p_splitter_il_db * math.log2(max(m, 2))
    p -= optics.p_mrr_w_il_db
    p -= obl_passes * (n - 1) * optics.p_mrm_obl_db
    p -= penalty_db
    p -= 10.0 * math.log10(n)                  # comb power split over N lambdas
    return p


def obl_passes_for(backend: str) -> int:
    return 1 if backend.replace("_bpca", "") == "heana" else 2


def max_dpe_size(backend: str, bits: float, data_rate_gsps: float,
                 optics: OpticalParams | None = None) -> int:
    """Largest N with P_O/p(N) >= P_PD-opt(bits, DR).  0 if infeasible at N=1.

    P_O/p(N) is strictly decreasing in N, so we binary-search the crossing.
    """
    optics = optics or OpticalParams()
    key = backend.replace("_bpca", "")
    penalty = NETWORK_PENALTY_DB[key]
    obl_passes = obl_passes_for(backend)
    try:
        p_req = noise.p_pd_opt_dbm(bits, data_rate_gsps, optics)
    except ValueError:
        return 0

    def feasible(n: int) -> bool:
        return output_power_dbm(n, n, penalty, optics, obl_passes) >= p_req

    if not feasible(1):
        return 0
    lo, hi = 1, 1
    while hi < MAX_N and feasible(hi):
        lo, hi = hi, hi * 2
    hi = min(hi, MAX_N)
    # invariant: feasible(lo), not feasible(hi) (unless hi == MAX_N feasible)
    if feasible(hi):
        return hi
    while hi - lo > 1:
        mid = (lo + hi) // 2
        if feasible(mid):
            lo = mid
        else:
            hi = mid
    return lo


def fig9_surface(backends: Iterable[str] = ("amw", "maw", "heana"),
                 bit_range: Iterable[int] = range(1, 9),
                 data_rates: Iterable[float] = (1.0, 5.0, 10.0),
                 optics: OpticalParams | None = None,
                 ) -> Dict[Tuple[str, int, float], int]:
    """The full Fig. 9 surface: N for every (backend, B, DR)."""
    out = {}
    for be in backends:
        for b in bit_range:
            for dr in data_rates:
                out[(be, b, dr)] = max_dpe_size(be, b, dr, optics)
    return out


# Paper Table 2: DPU size and count at 4-bit precision, area-matched to
# HEANA(N=83) with 50 DPUs.  Used by the perf model's equal-area comparison.
PAPER_TABLE2 = {
    # backend: {dr_gsps: (N, dpu_count)}
    "amw":   {1.0: (36, 207), 5.0: (17, 900), 10.0: (12, 1950)},
    "maw":   {1.0: (43, 280), 5.0: (21, 1100), 10.0: (15, 1610)},
    "heana": {1.0: (83, 52), 5.0: (42, 180), 10.0: (30, 320)},
}


def table2_dpu_config(backend: str, data_rate_gsps: float) -> Tuple[int, int]:
    """(N, dpu_count) for the equal-area system evaluation (paper Table 2)."""
    key = backend.replace("_bpca", "")
    return PAPER_TABLE2[key][data_rate_gsps]

"""Photonic GEMM numerics simulation — the paper's C1+C3 as a drop-in matmul.

``photonic_dot_general(x, w, cfg, key)`` contracts the last axis of ``x``
with the first axis of ``w`` the way a HEANA / AMW / MAW DPU would:

  1. operands are symmetrically quantized to ``cfg.bits`` (weights get a
     per-output-channel scale, activations a per-tensor scale),
  2. the K dimension is tiled into DPE-sized chunks of ``cfg.dpe_size`` (=N,
     the optical dot-product width — one temporal fold per chunk),
  3. each chunk psum is an exact integer dot product (hitless TAOM array +
     one BPD integration cycle) plus a Gaussian detection-noise draw whose
     sigma comes from the link budget at the operating point (Eqs. 1-3),
  4. accumulation policy:
       * HEANA (and *_bpca variants): psums accrue on a BPCA capacitor in
         the analog domain; ONE ADC conversion per output value.
       * AMW / MAW: every chunk psum is ADC-converted immediately and the
         chunks are reduced digitally (their DPUs have no charge-domain
         accumulator) — quantization error is injected once per chunk.
       * int_quant: exact integer accumulate, float readout (ideal int-B
         reference used by the Table 4 experiment).
       * exact: plain matmul (no photonics at all).
  5. the result is rescaled to float by the operand scales.

Differentiability: the simulation is wrapped in a straight-through-estimator
``custom_vjp`` (gradients of an exact matmul), which makes every model in
the zoo trainable *through* the photonic numerics (photonic-aware QAT — a
beyond-paper feature).

This module is also the pure-jnp oracle for the Pallas kernel
(``kernels/taom_gemm.py`` must match it bit-for-bit modulo float summation
order when fed the same pre-sampled noise).
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import bpca, scalability
from repro.core.taom import quantize
from repro.core.types import Backend, PhotonicConfig

ANALOG_CARRY_BACKENDS = (Backend.HEANA, Backend.HEANA_AMW_BPCA,
                         Backend.HEANA_MAW_BPCA)
CHUNK_ADC_BACKENDS = (Backend.AMW, Backend.MAW)


def operating_pd_power_dbm(cfg: PhotonicConfig) -> float:
    """Optical power at the photodiode for the configured DPE size."""
    if cfg.pd_power_dbm is not None:
        return cfg.pd_power_dbm
    key = cfg.backend.value.replace("_bpca", "")
    if key == "exact" or key == "int_quant":
        key = "heana"
    from repro.core.types import NETWORK_PENALTY_DB
    return scalability.output_power_dbm(
        cfg.dpe_size, cfg.dpe_size, NETWORK_PENALTY_DB[key], cfg.optics,
        scalability.obl_passes_for(key))


def detection_sigma(cfg: PhotonicConfig) -> float:
    """Per-cycle detection-noise sigma in integer product units."""
    if not cfg.noise_enabled:
        return 0.0
    return bpca.detection_sigma_int(cfg, operating_pd_power_dbm(cfg))


def design_point(backend: Backend, bits: int, data_rate_gsps: float,
                 **overrides) -> PhotonicConfig:
    """A self-consistent PhotonicConfig at the scalability design point.

    Thin wrapper over core.hw.OperatingPoint (the single source of truth
    for solver-derived hardware): N = max_dpe_size(backend, bits, DR), at
    which the link-budget power delivers exactly ``bits`` ENOB (paper
    Fig. 9 operating points).  Falls back to N=1 when the precision is
    optically infeasible (OperatingPoint itself refuses infeasible
    points; this entry keeps the historical lenient behavior for the
    accuracy-surface sweeps that deliberately cross the RIN cliff).
    """
    from repro.core import hw
    key = backend.value.replace("_bpca", "")
    if scalability.max_dpe_size(key, bits, data_rate_gsps) < 1:
        return PhotonicConfig(backend=backend, bits=bits, dpe_size=1,
                              data_rate_gsps=data_rate_gsps, **overrides)
    op = hw.OperatingPoint.design(backend.value, bits=bits,
                                  data_rate_gsps=data_rate_gsps)
    return op.kernel_config(backend=backend, **overrides)


def num_chunks(k: int, cfg: PhotonicConfig) -> int:
    return max(1, math.ceil(k / cfg.dpe_size))


def noise_shape(x_shape: Tuple[int, ...], w_shape: Tuple[int, ...],
                cfg: PhotonicConfig) -> Tuple[int, ...]:
    """Shape of the pre-sampled standard-normal noise tensor.

    HEANA-style analog carry needs one draw per output element; chunk-ADC
    backends need one draw per (chunk, output) because noise interacts with
    the per-chunk rounding.
    """
    batch = x_shape[:-1]
    d = w_shape[-1]
    if cfg.backend in CHUNK_ADC_BACKENDS:
        return (*batch, num_chunks(x_shape[-1], cfg), d)
    return (*batch, d)


def sample_noise(key: jax.Array, x_shape: Tuple[int, ...],
                 w_shape: Tuple[int, ...], cfg: PhotonicConfig,
                 dtype=jnp.float32) -> jnp.ndarray:
    return jax.random.normal(key, noise_shape(x_shape, w_shape, cfg), dtype)


def _chunked(q: jnp.ndarray, n: int, n_chunks: int, axis_last: bool
             ) -> jnp.ndarray:
    """Zero-pad K to n_chunks*n and reshape into chunks."""
    k = q.shape[-1] if axis_last else q.shape[0]
    pad = n_chunks * n - k
    if axis_last:
        if pad:
            q = jnp.pad(q, [(0, 0)] * (q.ndim - 1) + [(0, pad)])
        return q.reshape(*q.shape[:-1], n_chunks, n)
    if pad:
        q = jnp.pad(q, [(0, pad)] + [(0, 0)] * (q.ndim - 1))
    return q.reshape(n_chunks, n, *q.shape[1:])


def _simulate(x: jnp.ndarray, w: jnp.ndarray, noise: jnp.ndarray,
              cfg: PhotonicConfig) -> jnp.ndarray:
    """Forward photonic simulation.  noise: standard normal, pre-sampled."""
    if cfg.backend == Backend.EXACT:
        return x @ w

    f32 = jnp.float32
    xq, sx = quantize(x.astype(f32), cfg.bits, axis=None)          # scalar
    wq, sw = quantize(w.astype(f32), cfg.bits, axis=0)             # (1, D)
    k = x.shape[-1]
    n_chunks = num_chunks(k, cfg)
    xc = _chunked(xq, cfg.dpe_size, n_chunks, axis_last=True)      # (...,C,N)
    wc = _chunked(wq, cfg.dpe_size, n_chunks, axis_last=False)     # (C,N,D)
    # One BPD integration cycle per chunk: exact integer psum.
    psums = jnp.einsum("...cn,cnd->...cd", xc, wc,
                       preferred_element_type=f32)                 # (...,C,D)
    sigma = detection_sigma(cfg)

    if cfg.backend == Backend.INT_QUANT:
        total = jnp.sum(psums, axis=-2)
    elif cfg.backend in CHUNK_ADC_BACKENDS:
        # AMW/MAW: noise + ADC per chunk, digital reduction.
        noisy = psums + sigma * noise
        fs = jax.lax.stop_gradient(jnp.max(jnp.abs(noisy)))
        quantized = bpca.adc_readout(noisy, cfg.adc_bits, fs)
        total = jnp.sum(quantized, axis=-2)
    else:
        # HEANA: analog carry across chunks (BPCA), single ADC per output.
        acc = jnp.sum(psums, axis=-2)
        acc = acc + sigma * jnp.sqrt(float(n_chunks)) * noise
        fs = jax.lax.stop_gradient(jnp.max(jnp.abs(acc)))
        total = bpca.adc_readout(acc, cfg.adc_bits, fs)

    return (total * (sx * sw)).astype(x.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _ste_dot(x, w, noise, cfg):
    return _simulate(x, w, noise, cfg)


def _ste_fwd(x, w, noise, cfg):
    return _simulate(x, w, noise, cfg), (x, w)


def _ste_bwd(cfg, res, g):
    x, w = res
    gx = jnp.einsum("...d,kd->...k", g, w).astype(x.dtype)
    batch = tuple(range(g.ndim - 1))
    gw = jnp.tensordot(x, g, axes=(batch, batch)).astype(w.dtype)
    return gx, gw, None


_ste_dot.defvjp(_ste_fwd, _ste_bwd)


def photonic_dot_general(x: jnp.ndarray, w: jnp.ndarray, cfg: PhotonicConfig,
                         key: Optional[jax.Array] = None) -> jnp.ndarray:
    """Drop-in matmul with HEANA/AMW/MAW numerics (see module docstring).

    x: (..., K), w: (K, D) -> (..., D).  ``key`` enables detection noise;
    with ``key=None`` (or cfg.noise_enabled=False) the simulation is
    deterministic (quantization + accumulation policy only).
    """
    if cfg.backend == Backend.EXACT:
        return x @ w
    if key is not None and cfg.noise_enabled:
        noise = sample_noise(key, x.shape, w.shape, cfg)
    else:
        noise = jnp.zeros(noise_shape(x.shape, w.shape, cfg), jnp.float32)
    return _ste_dot(x, w, noise, cfg)


def device_level_dot(x: jnp.ndarray, w: jnp.ndarray, cfg: PhotonicConfig,
                     key: Optional[jax.Array] = None) -> jnp.ndarray:
    """Explicit TAOM->lanes->BPCA path (device-level, HEANA backend only).

    Slower but structurally faithful: used by tests to pin the fused
    ``photonic_dot_general`` to the device model.
    """
    from repro.core import taom as taom_mod
    assert cfg.backend in ANALOG_CARRY_BACKENDS
    f32 = jnp.float32
    xq, sx = quantize(x.astype(f32), cfg.bits, axis=None)
    wq, sw = quantize(w.astype(f32), cfg.bits, axis=0)
    k = x.shape[-1]
    n_chunks = num_chunks(k, cfg)
    xc = _chunked(xq, cfg.dpe_size, n_chunks, axis_last=True)   # (...,C,N)
    wc = _chunked(wq, cfg.dpe_size, n_chunks, axis_last=False)  # (C,N,D)
    # Explicit per-wavelength TAOM products on the balanced lanes, then one
    # BPD integration per chunk cycle: (...,C,N,1) * (C,N,D) -> (...,C,N,D).
    prod_through, prod_drop = taom_mod.taom_array_products(
        xc[..., :, :, None], wc, cfg)
    psums = bpca.integrate_cycle(prod_through, prod_drop, axis=-2)  # (...,C,D)
    sigma = detection_sigma(cfg)
    noise_key = key if (key is not None and cfg.noise_enabled) else None
    acc = bpca.accumulate(jnp.moveaxis(psums, -2, -1), cfg=cfg,
                          sigma_int=sigma, key=noise_key, chunk_axis=-1)
    fs = jnp.max(jnp.abs(acc))
    total = bpca.adc_readout(acc, cfg.adc_bits, fs)
    return (total * (sx * sw)).astype(x.dtype)

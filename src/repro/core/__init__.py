"""HEANA core: the paper's contribution as composable JAX modules."""
from repro.core.types import (Backend, Dataflow, OpticalParams,
                              PhotonicConfig, TPU_V5E, TpuTarget)
from repro.core.hw import (EventEnergies, OperatingPoint, TraceEnergy,
                           check_kernel_plan_coherence,
                           kernel_plan_mismatches, trace_energy)
from repro.core.photonic_gemm import (photonic_dot_general, device_level_dot,
                                      detection_sigma, sample_noise,
                                      noise_shape, num_chunks)
from repro.core.scalability import (max_dpe_size, output_power_dbm,
                                    fig9_surface, table2_dpu_config)
from repro.core.taom import quantize, taom_multiply, encode_time_amplitude
from repro.core import bpca, noise

__all__ = [
    "Backend", "Dataflow", "OpticalParams", "PhotonicConfig", "TPU_V5E",
    "TpuTarget", "photonic_dot_general", "device_level_dot",
    "detection_sigma", "sample_noise", "noise_shape", "num_chunks",
    "OperatingPoint", "EventEnergies", "TraceEnergy", "trace_energy",
    "kernel_plan_mismatches", "check_kernel_plan_coherence",
    "max_dpe_size", "output_power_dbm", "fig9_surface", "table2_dpu_config",
    "quantize", "taom_multiply", "encode_time_amplitude", "bpca", "noise",
]

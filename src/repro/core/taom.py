"""TAOM — hybrid Time-Amplitude analog Optical Multiplier (paper §3.2.2).

A TAOM is a single add-drop microring modulator driven by a hybrid
time-amplitude electrical signal:

  * the *weight* w is produced by a DAC as an amplitude-analog level,
  * the *activation* a is produced by a digital pulse converter (DPC) as a
    time-analog pulse width,
  * an RF mixer multiplies them; the MRM transfers the product onto the
    optical carrier, so the *area* of the optical output pulse equals
    a_q * w_q (in integer units after quantization),
  * the sign of the product selects the through (+) or drop (-) port, i.e.
    the result is a *balanced* optical pulse pair.

This module is the explicit device-level model.  ``photonic_gemm`` fuses the
same math for speed; ``tests/test_photonic_gemm.py`` asserts the two paths
agree exactly when noise is disabled.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.core.types import TAOM_MAX_PULSE_WIDTH_NS, PhotonicConfig


def quantize(x: jnp.ndarray, bits: int, axis=None, keepdims: bool = True,
             eps: float = 1e-12) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric B-bit quantization: returns integer-valued q and scale s.

    x ~= q * s with q in [-qmax, qmax].  ``axis=None`` => per-tensor scale;
    an int/tuple axis gives per-channel scales (reduced over ``axis``).
    """
    qmax = (1 << bits) - 1
    absmax = jnp.max(jnp.abs(x), axis=axis,
                     keepdims=(axis is not None) and keepdims)
    # Explicit reciprocal multiply, NOT division by the constant qmax: XLA
    # rewrites x/const into x*(1/const) when jit-compiling whole programs
    # but not op-by-op, so a division here would make jitted and eager
    # forwards differ by 1 ULP in scale — enough to cross a downstream ADC
    # rounding boundary and break the executor's bit-exactness contract.
    scale = jnp.maximum(absmax, eps) * (1.0 / qmax)
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
    return q, scale


def encode_time_amplitude(a_q: jnp.ndarray, w_q: jnp.ndarray, bits: int,
                          ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Map integer operands to physical drive signals.

    Returns (pulse_width_ns, amplitude_frac): the DPC pulse width carrying
    |a_q| and the DAC amplitude fraction carrying |w_q| (sign tracked by the
    caller through the balanced ports).
    """
    qmax = (1 << bits) - 1
    pulse_width_ns = jnp.abs(a_q) / qmax * TAOM_MAX_PULSE_WIDTH_NS
    amplitude_frac = jnp.abs(w_q) / qmax
    return pulse_width_ns, amplitude_frac


def taom_multiply(a_q: jnp.ndarray, w_q: jnp.ndarray,
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Balanced optical pulse areas (through, drop) for integer operands.

    area_through - area_drop == a_q * w_q  (integer product units), with the
    positive part routed to the through port and the negative part to the
    drop port, exactly as the balanced detection in Fig. 4(b) expects.
    """
    prod = a_q * w_q
    through = jnp.maximum(prod, 0.0)
    drop = jnp.maximum(-prod, 0.0)
    return through, drop


def taom_array_products(a_q: jnp.ndarray, w_q: jnp.ndarray,
                        cfg: PhotonicConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Products of a spectrally hitless TAOM array.

    a_q, w_q: (..., n) integer operand vectors, one entry per wavelength.
    Returns the (through, drop) pulse-area vectors that the aggregation
    lanes deliver to the BPCA.  The hitless arrangement means no crosstalk
    term couples entries — products are exact per wavelength (the paper's
    point: crosstalk is eliminated structurally, and shows up only in the
    link-budget penalty used by the scalability analysis).
    """
    del cfg  # hitless: no crosstalk coupling term
    return taom_multiply(a_q, w_q)

"""Energy accounting for the HEANA system-level model (paper Table 3).

All per-event energies derive from Table 3 power x latency products, plus
two constants Table 3 omits:

  * ADC conversion energy: Table 3 lists DACs only.  We use 1.5 pJ/conv
    (8-bit, ~1 GS/s SAR ADC — the figure used by Al-Qadasi et al. [2],
    the same source the paper takes Eqs. 1-3 from).  Documented deviation,
    DESIGN.md §6.
  * average thermo-optic tuning excursion: 0.5 FSR (uniformly distributed
    weight updates), applied to the 275 mW/FSR figure for the 4 us hold.
"""
from __future__ import annotations

import dataclasses

from repro.core.types import (EO_TUNING_LATENCY_NS, EO_TUNING_POWER_W_PER_FSR,
                              PERIPHERALS, TO_TUNING_LATENCY_NS,
                              TO_TUNING_POWER_W_PER_FSR, dbm_to_watt)

ADC_ENERGY_PJ = 1.5            # per conversion [2]
# Average thermo-optic excursion per weight update, as a fraction of one
# FSR.  Table 3 gives only the full-FSR power (275 mW); the per-update
# excursion is not published.  0.05 FSR is calibrated once against the
# paper's FPS/W gmean anchor (HEANA-OS ~89x/84x vs AMW/MAW at 1 GS/s,
# Fig. 11b) and held fixed for every other prediction (DESIGN.md §6).
AVG_TUNING_EXCURSION_FSR = 0.05

# Per-event energies (joules), from Table 3 power x latency.
E_EDRAM_ACCESS = PERIPHERALS["edram"].power_mw * 1e-3 * \
    PERIPHERALS["edram"].latency_ns * 1e-9
E_REDUCTION_PASS = PERIPHERALS["reduction_network"].power_mw * 1e-3 * \
    PERIPHERALS["reduction_network"].latency_ns * 1e-9
E_ACTIVATION = PERIPHERALS["activation_unit"].power_mw * 1e-3 * \
    PERIPHERALS["activation_unit"].latency_ns * 1e-9
E_ADC_CONV = ADC_ENERGY_PJ * 1e-12
E_TO_TUNE_PER_RING = TO_TUNING_POWER_W_PER_FSR * AVG_TUNING_EXCURSION_FSR * \
    TO_TUNING_LATENCY_NS * 1e-9
E_EO_TUNE_PER_RING = EO_TUNING_POWER_W_PER_FSR * AVG_TUNING_EXCURSION_FSR * \
    EO_TUNING_LATENCY_NS * 1e-9


DAC_NATIVE_RATE_GSPS = {"dac_heana": 10.0,    # [18]: 10 GS/s 4-bit DAC
                        "dac_baseline": 1.0}  # [41]: 1 GS/s current-steering


def dac_energy_per_symbol(backend: str, data_rate_gsps: float) -> float:
    """DAC energy per converted operand symbol (J).

    Table 3 quotes each DAC's power at its *native* conversion rate, so the
    per-symbol energy is P / native_rate (2.6 pJ for HEANA's 10 GS/s DAC,
    12.5 pJ for the AMW/MAW baseline DAC), independent of the system DR.
    """
    del data_rate_gsps
    key = "dac_heana" if backend.startswith("heana") else "dac_baseline"
    p = PERIPHERALS[key].power_mw * 1e-3
    return p / (DAC_NATIVE_RATE_GSPS[key] * 1e9)


@dataclasses.dataclass
class EnergyBreakdown:
    laser: float = 0.0
    dac: float = 0.0
    adc: float = 0.0
    tuning: float = 0.0
    buffer: float = 0.0
    reduction: float = 0.0
    static: float = 0.0

    @property
    def total(self) -> float:
        return (self.laser + self.dac + self.adc + self.tuning +
                self.buffer + self.reduction + self.static)


def static_power_w(n_dpus: int) -> float:
    """Always-on peripheral power per accelerator (Table 3): IO interface,
    eDRAM controllers, bus, router, pooling/activation units per tile
    (4 DPUs per tile, Fig. 10)."""
    tiles = max(1, n_dpus // 4)
    per_tile = (PERIPHERALS["edram"].power_mw + PERIPHERALS["bus"].power_mw +
                PERIPHERALS["pooling_unit"].power_mw +
                PERIPHERALS["activation_unit"].power_mw)
    chip = (PERIPHERALS["io_interface"].power_mw +
            PERIPHERALS["router"].power_mw)
    return (tiles * per_tile + chip) * 1e-3


def laser_power_w(n_wavelengths: int, p_laser_dbm: float) -> float:
    """Comb laser electrical power for one DPU: N lines at P_laser each,
    assuming 20% wall-plug efficiency (standard comb-laser figure)."""
    optical = n_wavelengths * dbm_to_watt(p_laser_dbm)
    return optical / 0.20

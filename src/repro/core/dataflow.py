"""Dataflow engine: loop orders, tiling schedules, and buffer-access counts.

Reproduces the paper's §2.1/Fig. 1 accounting and the §4 mapping (temporal
switches ``ts`` and temporal folds ``tf``) that the perf model consumes.

Counting convention (documented for the Fig. 1 table reproduction):
  * GEMM I(C x K) @ W(K x D) -> O(C x D), DPU has M DPEs of size N,
    F = ceil(K / N) temporal folds per output value.
  * accesses are counted at *element* granularity against the unified
    buffer, per the innermost loop that re-touches the operand:
      - OS (loops c, d, k): every (c, d) walks all of K for both I and W;
        O is written exactly once (psums never leave the DPU).
      - IS (loops c, k, d): I read once (C*K); W re-read for every c;
        psums for a given output are produced F times spread across
        non-consecutive cycles -> without BPCA each one is written and
        all re-read for reduction.
      - WS (loops k, d, c): W read once (K*D); I re-read for every d;
        psum traffic as IS.
  * with a BPCA, psum write/read traffic collapses to zero (in-situ analog
    accumulation) as long as the in-flight outputs fit the p=4608 capacitor
    bank; the excess fraction spills and is accounted like the non-BPCA
    case (core.perf_model handles the spill).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

from repro.core.types import BPCA_NUM_CAPACITORS, Dataflow


@dataclasses.dataclass(frozen=True)
class GemmShape:
    c: int
    k: int
    d: int

    @property
    def outputs(self) -> int:
        return self.c * self.d


@dataclasses.dataclass(frozen=True)
class BufferAccesses:
    """Element-granularity unified-buffer accesses for one GEMM."""
    input_reads: int
    weight_reads: int
    output_writes: int
    psum_writes: int
    psum_reads: int

    @property
    def total(self) -> int:
        return (self.input_reads + self.weight_reads + self.output_writes +
                self.psum_writes + self.psum_reads)


def buffer_accesses(g: GemmShape, dataflow: Dataflow, dpe_size: int,
                    with_bpca: bool) -> BufferAccesses:
    """Unified-buffer access counts for one GEMM under a dataflow."""
    f = max(1, math.ceil(g.k / dpe_size))
    if dataflow == Dataflow.OS:
        # OS walks K for every (c, d) pair.
        reads_i = g.c * g.d * g.k
        reads_w = g.c * g.d * g.k
        psw = psr = 0                      # accumulate in place (register/cap)
    elif dataflow == Dataflow.IS:
        reads_i = g.c * g.k                # inputs stationary: read once
        reads_w = g.c * g.k * g.d          # weights re-streamed per row
        psw = g.outputs * f
        psr = g.outputs * f                # write each psum + re-read to reduce
    else:  # WS
        reads_w = g.k * g.d                # weights stationary: read once
        reads_i = g.c * g.k * g.d          # inputs re-streamed per column
        psw = g.outputs * f
        psr = g.outputs * f
    if with_bpca:
        psw = psr = 0
    return BufferAccesses(reads_i, reads_w, g.outputs, psw, psr)


@dataclasses.dataclass(frozen=True)
class Schedule:
    """Per-GEMM DPU schedule counts for the event-driven perf model.

    cycles:          BPD integration cycles needed on one DPU
    weight_switches: number of times the DPU's weight operands change
    input_switches:  number of times the DPU's input operands change
    psum_events:     psums that leave the DPU (ADC + buffer round trip)
    adc_conversions: total ADC conversions
    inflight_outputs: outputs whose psums are concurrently parked (BPCA
                     capacitor pressure for IS/WS)
    """
    cycles: int
    weight_switches: int
    input_switches: int
    psum_events: int
    adc_conversions: int
    inflight_outputs: int


def schedule(g: GemmShape, dataflow: Dataflow, n: int, m: int,
             with_bpca: bool, os_speedup: int = 1) -> Schedule:
    """Schedule counts for one GEMM on a DPU with M DPEs of size N.

    ``os_speedup`` models HEANA's 10x coherent pulse accumulation in OS
    dataflow (TAOM pulses are 100 ps vs the BPD's 1 ns window) — folds for
    the *same* output value stream back-to-back into one integration
    window, so the fold loop runs up to 10x faster (paper §3.2.4).
    """
    f = max(1, math.ceil(g.k / n))
    work = g.c * g.d * f                    # (output, fold) pairs
    speed = os_speedup if dataflow == Dataflow.OS else 1
    cycles = math.ceil(work / (m * speed))

    d_tiles = math.ceil(g.d / m)
    if dataflow == Dataflow.OS:
        # per output tile: F folds, new weights AND inputs each fold
        weight_switches = g.c * d_tiles * f
        input_switches = g.c * d_tiles * f
        inflight = m                        # one tile of outputs in flight
    elif dataflow == Dataflow.IS:
        # inputs held per (row, fold); all D columns swept per hold
        weight_switches = g.c * f * d_tiles
        input_switches = g.c * f
        inflight = g.d                      # a whole output row in flight
    else:  # WS
        # weights held per (fold, d tile); all C rows swept per hold
        weight_switches = f * d_tiles
        input_switches = f * d_tiles * g.c
        inflight = g.c                      # a whole output column in flight
    if with_bpca:
        spill = max(0, inflight - BPCA_NUM_CAPACITORS) / max(inflight, 1)
        psum_events = int(g.outputs * (f - 1) * spill)
        adc = g.outputs + psum_events
    else:
        psum_events = g.outputs * (f - 1)   # every non-final fold round-trips
        adc = g.outputs * f
    return Schedule(cycles, weight_switches, input_switches, psum_events,
                    adc, inflight)


@dataclasses.dataclass(frozen=True)
class StreamCounts:
    """Operand stream volumes for the energy model (FIFO-reuse aware).

    ``dac_*``: DAC conversion events (one per operand value entering the
    analog domain; the *stationary* operand of a dataflow is sample-and-
    held, so it converts only when it actually changes).
    ``buf_*``: unified-buffer element fetches, with per-DPE FIFO replay of
    held operands (this is what Fig. 10's dedicated FIFOs buy; the
    pedagogical no-reuse counts live in ``buffer_accesses``).
    DPEs hold distinct output columns; inputs broadcast across DPEs.
    """
    dac_weight: int
    dac_input: int
    buf_weight: int
    buf_input: int


def stream_counts(g: GemmShape, dataflow: Dataflow, n: int, m: int
                  ) -> StreamCounts:
    f = max(1, math.ceil(g.k / n))
    kp = f * n                       # padded contraction length
    d_tiles = math.ceil(g.d / m)
    if dataflow == Dataflow.OS:
        # (d, c, k) order: weights replayed from FIFO across rows but
        # re-converted every fold; inputs re-streamed per column tile.
        dac_w = g.c * g.d * kp
        dac_i = g.c * kp * d_tiles
        buf_w = kp * g.d
        buf_i = g.c * kp * d_tiles
    elif dataflow == Dataflow.IS:
        # inputs sample-and-held per (row, fold); weights sweep columns.
        dac_w = g.c * g.d * kp
        dac_i = g.c * kp
        buf_w = g.c * g.d * kp       # weight working set too big for FIFOs
        buf_i = g.c * kp
    else:  # WS
        # weights sample-and-held per (fold, d tile); inputs stream.
        dac_w = kp * g.d
        dac_i = g.c * kp * d_tiles
        buf_w = kp * g.d
        buf_i = g.c * kp * d_tiles
    return StreamCounts(dac_w, dac_i, buf_w, buf_i)


def fig1_table(g: GemmShape, dpe_size: int = 83,
               with_bpca: bool = False) -> Dict[str, Dict[str, int]]:
    """The Fig. 1 comparison table: accesses per dataflow for one GEMM."""
    out = {}
    for df in (Dataflow.OS, Dataflow.IS, Dataflow.WS):
        acc = buffer_accesses(g, df, dpe_size, with_bpca)
        out[df.value] = {
            "input_reads": acc.input_reads,
            "weight_reads": acc.weight_reads,
            "output_writes": acc.output_writes,
            "psum_accesses": acc.psum_writes + acc.psum_reads,
            "total": acc.total,
        }
    return out

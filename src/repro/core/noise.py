"""Photodetection noise model — paper Eqs. 1-2 (adopted from Al-Qadasi et al.).

The balanced photodiode noise-current spectral density (A/sqrt(Hz)):

    beta = sqrt(2 q (R P + I_d) + 4 k T / R_L + R^2 P^2 RIN)
         + sqrt(2 q I_d + 4 k T / R_L)

(the two terms are the two photodiodes of the balanced pair: the signal arm
sees shot + thermal + RIN, the reference arm sees dark-current shot +
thermal).  The effective number of bits resolvable at data-rate DR is the
standard ENOB relation:

    B = (20 log10( R P / (beta sqrt(DR / sqrt(2))) ) - 1.76) / 6.02

These closed forms serve double duty here:
  * scalability.py inverts them for P_PD-opt(B, DR)  (paper Fig. 9), and
  * photonic_gemm.py converts them into a Gaussian noise sigma on the analog
    dot-product value (paper Fig. 5's accuracy/precision surfaces).
"""
from __future__ import annotations

import math

from repro.core.types import K_BOLTZMANN, Q_ELECTRON, OpticalParams, dbm_to_watt


def beta_noise_density(p_pd_watt: float, optics: OpticalParams) -> float:
    """Noise-current spectral density of the balanced pair (A/sqrt(Hz))."""
    r = optics.responsivity
    thermal = 4.0 * K_BOLTZMANN * optics.temperature / optics.r_load
    shot_sig = 2.0 * Q_ELECTRON * (r * p_pd_watt + optics.i_dark)
    rin = (r * p_pd_watt) ** 2 * optics.rin_lin
    shot_ref = 2.0 * Q_ELECTRON * optics.i_dark
    return math.sqrt(shot_sig + thermal + rin) + math.sqrt(shot_ref + thermal)


def noise_current_rms(p_pd_watt: float, data_rate_gsps: float,
                      optics: OpticalParams) -> float:
    """RMS noise current (A) at the receiver for the given data rate."""
    bandwidth = data_rate_gsps * 1e9 / math.sqrt(2.0)
    return beta_noise_density(p_pd_watt, optics) * math.sqrt(bandwidth)


def snr(p_pd_watt: float, data_rate_gsps: float, optics: OpticalParams) -> float:
    """Linear signal-to-noise ratio of a full-scale detection event."""
    signal = optics.responsivity * p_pd_watt
    return signal / noise_current_rms(p_pd_watt, data_rate_gsps, optics)


def enob(p_pd_dbm: float, data_rate_gsps: float, optics: OpticalParams) -> float:
    """Effective number of bits — paper Eq. 1."""
    p = dbm_to_watt(p_pd_dbm)
    s = snr(p, data_rate_gsps, optics)
    if s <= 0.0:
        return -float("inf")
    return (20.0 * math.log10(s) - 1.76) / 6.02


def p_pd_opt_dbm(bits: float, data_rate_gsps: float, optics: OpticalParams,
                 lo_dbm: float = -60.0, hi_dbm: float = 30.0,
                 tol: float = 1e-6) -> float:
    """Invert Eq. 1: minimum PD optical power (dBm) for ``bits`` ENOB.

    ``enob`` is monotonically increasing in power, so bisection is exact.
    """
    if enob(hi_dbm, data_rate_gsps, optics) < bits:
        raise ValueError(
            f"{bits} bits unreachable at DR={data_rate_gsps} GS/s "
            f"below {hi_dbm} dBm")
    lo, hi = lo_dbm, hi_dbm
    while hi - lo > tol:
        mid = 0.5 * (lo + hi)
        if enob(mid, data_rate_gsps, optics) >= bits:
            hi = mid
        else:
            lo = mid
    return hi


def relative_noise_sigma(p_pd_dbm: float, data_rate_gsps: float,
                         optics: OpticalParams) -> float:
    """Gaussian sigma of one detection event, relative to full scale.

    A full-scale analog pulse detected with linear SNR ``s`` carries additive
    noise with sigma = 1/s of full scale.  photonic_gemm scales this to
    integer product units.
    """
    p = dbm_to_watt(p_pd_dbm)
    return 1.0 / snr(p, data_rate_gsps, optics)

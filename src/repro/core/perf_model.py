"""Event-driven system-level performance model (paper §6, Figs. 11-14).

Models a whole accelerator (Fig. 10: tiles of 4 DPUs, unified buffer,
H-tree) inferring a CNN: every conv layer's GEMM is scheduled onto the
DPUs under a chosen dataflow, and latency/energy are accumulated from the
schedule counts of core.dataflow plus the device constants of core.types /
core.energy.

Latency model per GEMM (on one DPU, then divided by the DPU count):
    t = t_stream + t_weight_actuation + t_psum + t_readout
  * t_stream: cycles x symbol time (1/DR); HEANA-OS streams folds of the
    same output back-to-back at 10x (TAOM pulse width vs BPD window).
  * t_weight_actuation: per weight switch — thermo-optic 4 us for AMW/MAW
    (the reason their OS/IS dataflows collapse, paper §6.3), electro-optic
    at symbol rate for HEANA (cost already inside t_stream).
  * t_psum: non-BPCA psum round trips through ADC + eDRAM (bandwidth term:
    one access port per DPE FIFO, eDRAM latency per access beyond what the
    symbol pipeline hides) + reduction-network passes.
  * t_readout: one ADC + buffer write per finished output (pipelined;
    charged at the eDRAM latency beyond overlap).

Energy per GEMM: laser (comb lines x wall-plug), DACs (2 per TAOM for
HEANA — weight DAC + input DPC; 1 per MRM + thermo-optic weight drive for
AMW/MAW), ADC conversions, tuning, buffer accesses, reduction passes, plus
accelerator static power x latency.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, List

from repro.core import dataflow as df
from repro.core import energy as en
from repro.core.types import (PERIPHERALS, Dataflow, EO_TUNING_LATENCY_NS,
                              OS_COHERENT_PULSES_PER_CYCLE, OpticalParams,
                              TO_TUNING_LATENCY_NS)
from repro.models.cnn import CNN_ZOO, LayerGemm


@dataclasses.dataclass(frozen=True)
class AcceleratorConfig:
    """Whole-accelerator geometry the perf model / scheduler consume.

    Like types.PhotonicConfig this is a low-level carrier: derive it from
    a ``core.hw.OperatingPoint`` (``op.accelerator_config()``) so N and
    the DPU count stay functions of (backend, bits, DR) instead of
    hand-set knobs that can drift from the kernel config.
    """
    backend: str                 # heana | amw | maw | amw_bpca | maw_bpca
    dataflow: Dataflow
    data_rate_gsps: float
    n: int                       # DPE size (wavelengths per DPE)
    m: int                       # DPEs per DPU (= n, paper's assumption)
    n_dpus: int

    @classmethod
    def equal_area(cls, backend: str, dataflow: Dataflow,
                   data_rate_gsps: float) -> "AcceleratorConfig":
        """Paper Table 2: area-matched DPU counts at 4-bit precision.

        Delegates to core.hw.OperatingPoint.equal_area — the single
        source of truth for operating-point-derived hardware (prefer
        passing the OperatingPoint itself to the scheduler; it then rides
        along in the plan and pins the kernel config too).
        """
        from repro.core import hw
        return hw.OperatingPoint.equal_area(
            backend, dataflow, data_rate_gsps).accelerator_config()

    @property
    def has_bpca(self) -> bool:
        return self.backend == "heana" or self.backend.endswith("_bpca")

    @property
    def is_heana(self) -> bool:
        return self.backend == "heana"


@dataclasses.dataclass
class GemmCost:
    latency_s: float
    energy: en.EnergyBreakdown


def gemm_cost(g: df.GemmShape, acc: AcceleratorConfig,
              optics: OpticalParams | None = None) -> GemmCost:
    """Latency + energy of one GEMM executed across the whole accelerator."""
    optics = optics or OpticalParams()
    symbol_s = 1e-9 / acc.data_rate_gsps
    # OS coherent-pulse accumulation: TAOM pulses are 100 ps while the BPD
    # integration window is 1/DR — so OS packs min(10, 10/DR) folds of the
    # *same* output into one window (10x at 1 GS/s, 1x at 10 GS/s).
    os_speedup = max(1, round(OS_COHERENT_PULSES_PER_CYCLE /
                              acc.data_rate_gsps)) if acc.is_heana else 1
    sch = df.schedule(g, acc.dataflow, acc.n, acc.m, acc.has_bpca, os_speedup)

    # ---- latency on one DPU ----
    t_stream = sch.cycles * symbol_s
    if acc.is_heana:
        # both operands actuate electro-optically at symbol rate: free.
        t_weights = 0.0
    else:
        # thermo-optic weight actuation; all rings of a DPU tune in parallel
        t_weights = sch.weight_switches * TO_TUNING_LATENCY_NS * 1e-9
    edram_ns = PERIPHERALS["edram"].latency_ns
    red_ns = PERIPHERALS["reduction_network"].latency_ns
    # psum round trips: write+read, partially hidden behind streaming
    hidden_ns = 1e9 * symbol_s
    t_psum = sch.psum_events * max(0.0, 2 * edram_ns - hidden_ns) * 1e-9
    if not acc.has_bpca:
        t_psum += g.outputs * (math.ceil(g.k / acc.n) - 1) * red_ns * 1e-9 \
            / max(acc.m, 1)
    t_readout = g.outputs * max(0.0, edram_ns - hidden_ns) * 1e-9 \
        / max(acc.m, 1)
    t_dpu = t_stream + t_weights + t_psum + t_readout

    # GEMMs parallelize across DPUs (output tiling — embarrassingly
    # parallel).  This division applies to *every* term, including
    # t_weights: the schedule counts above are single-DPU aggregates for
    # the whole GEMM, and distributing output tiles over n_dpus also
    # distributes the weight switches — each DPU performs ~1/n_dpus of
    # them, and different DPUs actuate their rings concurrently.  (A
    # stationary-operand hold that spans tiles on several DPUs is
    # duplicated, not serialized, so thermo-optic actuation never becomes
    # a sequential bottleneck across DPUs.)
    latency = t_dpu / acc.n_dpus

    # ---- energy across the accelerator ----
    e = en.EnergyBreakdown()
    e.laser = en.laser_power_w(acc.n, optics.p_laser_dbm) * t_stream
    # Operand streams: DAC conversions (stationary operand sample-and-held)
    # and unified-buffer fetches (per-DPE FIFO replay of held operands).
    streams = df.stream_counts(g, acc.dataflow, acc.n, acc.m)
    e.dac = (streams.dac_weight + streams.dac_input) * \
        en.dac_energy_per_symbol(acc.backend, acc.data_rate_gsps)
    e.adc = sch.adc_conversions * en.E_ADC_CONV
    if acc.is_heana:
        e.tuning = 0.0   # EO drive energy folded into the (larger) DAC figure
    else:
        e.tuning = sch.weight_switches * acc.n * acc.m * en.E_TO_TUNE_PER_RING
    buf_accesses = (streams.buf_weight + streams.buf_input + g.outputs +
                    2 * sch.psum_events)
    e.buffer = buf_accesses * en.E_EDRAM_ACCESS
    if not acc.has_bpca:
        e.reduction = g.outputs * en.E_REDUCTION_PASS
    # note: static energy is added once at the CNN level (depends on total
    # wall-clock, not per-GEMM accounting)
    return GemmCost(latency, e)


# ---------------------------------------------------------------------------
# Plan-friendly cost API (consumed by repro.exec.scheduler)
# ---------------------------------------------------------------------------
def dataflow_costs(g: df.GemmShape, acc: AcceleratorConfig,
                   flows: Iterable[Dataflow] = tuple(Dataflow),
                   ) -> Dict[Dataflow, GemmCost]:
    """Cost of one GEMM under each candidate dataflow on the same hardware.

    The accelerator's own ``acc.dataflow`` is ignored — each candidate is
    evaluated with the dataflow swapped in, everything else held fixed.
    (Always at default OpticalParams: the plan cache keys on the
    accelerator config alone, so a non-default optics knob here would
    alias cache entries.)
    """
    return {flow: gemm_cost(g, dataclasses.replace(acc, dataflow=flow))
            for flow in flows}


def best_dataflow(g: df.GemmShape, acc: AcceleratorConfig,
                  flows: Iterable[Dataflow] = tuple(Dataflow),
                  objective: str = "latency",
                  ) -> tuple[Dataflow, GemmCost, Dict[Dataflow, GemmCost]]:
    """Argmin dataflow for one GEMM under ``objective``.

    objective: 'latency' | 'energy' | 'edp'.  Ties break deterministically
    by (secondary metric, enum order) so plans are reproducible.
    Returns (winner, winner's cost, all candidate costs).
    """
    costs = dataflow_costs(g, acc, flows)

    def score(item):
        flow, cost = item
        lat, e = cost.latency_s, cost.energy.total
        if objective == "latency":
            key = (lat, e)
        elif objective == "energy":
            key = (e, lat)
        elif objective == "edp":
            key = (lat * e, lat)
        else:
            raise ValueError(f"unknown objective: {objective!r}")
        return (*key, list(Dataflow).index(flow))

    flow, cost = min(costs.items(), key=score)
    return flow, cost, costs


_DYNAMIC_ENERGY_FIELDS = ("laser", "dac", "adc", "tuning", "buffer",
                          "reduction")


def layer_costs(layers, acc: AcceleratorConfig, batch: int = 1,
                dataflows: Iterable[Dataflow] | None = None,
                optics: OpticalParams | None = None) -> List[GemmCost]:
    """Per-layer GemmCosts with batch folded into rows and the layer's
    ``count`` applied — THE accounting path shared by the analytic model
    (``cnn_inference``) and the executed-trace side (core.hw.
    trace_energy): one implementation, so modeled and executed numbers
    cannot drift.

    ``layers`` is anything with ``.c/.k/.d/.count`` (LayerGemm rows, or
    a plan's LayerPlan entries with the batch already folded — pass
    ``batch=1`` then).  The returned costs carry no static-power share
    (that is a whole-network wall-clock term the callers add).
    """
    layers = list(layers)
    if dataflows is None:
        per_layer_acc = [acc] * len(layers)
    else:
        per_layer_acc = [dataclasses.replace(acc, dataflow=flow)
                         for flow in dataflows]
        if len(per_layer_acc) != len(layers):
            raise ValueError("dataflows must match layers one-to-one")
    out: List[GemmCost] = []
    for layer, layer_acc in zip(layers, per_layer_acc):
        g = df.GemmShape(layer.c * batch, layer.k, layer.d)
        cost = gemm_cost(g, layer_acc, optics)
        # `count` independent GEMM instances (depthwise groups): total DPU
        # work scales by count, still spread over the same n_dpus.
        e = en.EnergyBreakdown(**{
            f: getattr(cost.energy, f) * layer.count
            for f in _DYNAMIC_ENERGY_FIELDS})
        out.append(GemmCost(cost.latency_s * layer.count, e))
    return out


@dataclasses.dataclass
class InferenceResult:
    fps: float
    fps_per_watt: float
    latency_s: float
    energy_j: float
    breakdown: en.EnergyBreakdown


def cnn_inference(layers: Iterable[LayerGemm], acc: AcceleratorConfig,
                  batch: int = 1,
                  dataflows: Iterable[Dataflow] | None = None,
                  optics: OpticalParams | None = None,
                  ) -> InferenceResult:
    """FPS and FPS/W for a CNN (list of GEMM layers) on an accelerator.

    Batch size multiplies the Toeplitz row count C (paper evaluates
    batch = 1 and 256): weight-stationary schedules amortize their weight
    loads over the whole batch.

    ``dataflows`` optionally overrides ``acc.dataflow`` per layer (same
    length as ``layers``) — the mixed-dataflow execution a HEANA plan from
    repro.exec.scheduler describes.

    ``optics`` (default OpticalParams) scales the laser-energy term; a
    plan scheduled from an OperatingPoint with non-default optics passes
    them here so modeled totals match the point's physics.
    """
    costs = layer_costs(layers, acc, batch, dataflows, optics)
    total_t = 0.0
    total_e = en.EnergyBreakdown()
    for cost in costs:
        total_t += cost.latency_s
        for f in _DYNAMIC_ENERGY_FIELDS:
            setattr(total_e, f, getattr(total_e, f) + getattr(cost.energy, f))
    total_e.static = en.static_power_w(acc.n_dpus) * total_t
    fps = batch / total_t
    watts = total_e.total / total_t
    return InferenceResult(fps, fps / watts, total_t, total_e.total, total_e)


def evaluate_suite(backends: Iterable[str], dataflows: Iterable[Dataflow],
                   data_rates: Iterable[float], batch: int = 1,
                   cnns: Iterable[str] = tuple(CNN_ZOO),
                   ) -> Dict[tuple, InferenceResult]:
    """The full Figs. 11-14 grid."""
    out = {}
    for cnn_name in cnns:
        layers = CNN_ZOO[cnn_name]()
        for be in backends:
            for flow in dataflows:
                for dr in data_rates:
                    acc = AcceleratorConfig.equal_area(be, flow, dr)
                    out[(cnn_name, be, flow.value, dr)] = cnn_inference(
                        layers, acc, batch)
    return out


def gmean(vals: List[float]) -> float:
    if not vals:
        raise ValueError(
            "gmean of an empty sequence is undefined — the benchmark "
            "suite being aggregated produced no results (check upstream "
            "filters/failures)")
    return math.exp(sum(math.log(v) for v in vals) / len(vals))

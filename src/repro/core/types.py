"""Core configuration types and physical constants for the HEANA reproduction.

Everything here mirrors the paper's Tables 1 and 3 plus the TPU-v5e target
constants used by the roofline analysis (which are properties of the *host*
accelerator this framework runs on, not of the photonic hardware being
modeled).
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Optional

# ---------------------------------------------------------------------------
# Physical constants (SI)
# ---------------------------------------------------------------------------
Q_ELECTRON = 1.602176634e-19  # C
K_BOLTZMANN = 1.380649e-23    # J/K


# ---------------------------------------------------------------------------
# Paper Table 1: scalability-analysis parameters
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class OpticalParams:
    """Parameters of Eqs. 1-3 (paper Table 1).

    ``d_mrr_mm`` and ``p_smf_att_db`` are not given in Table 1; they are
    calibrated once against the paper's Fig. 9 anchor (N=83/36/43 at B=4,
    DR=1 GS/s) and then held fixed — see DESIGN.md §6.4.
    """
    p_laser_dbm: float = 10.0          # laser power intensity
    responsivity: float = 1.2          # R, A/W
    r_load: float = 50.0               # R_L, ohm
    i_dark: float = 35e-9              # I_d, A
    temperature: float = 300.0         # K
    rin_db_hz: float = -140.0          # relative intensity noise
    p_ec_il_db: float = 1.44           # fiber-to-chip coupling IL
    p_si_att_db_mm: float = 0.3        # Si waveguide propagation loss
    p_splitter_il_db: float = 0.01     # splitter IL (per split stage)
    p_mrm_il_db: float = 4.0           # microring modulator IL
    p_mrr_w_il_db: float = 0.01        # weight-bank MRR IL
    p_mrm_obl_db: float = 0.01         # out-of-band loss per non-resonant ring
    # Calibrated (DESIGN.md §6.4):
    d_mrr_mm: float = 0.02             # ring diameter / pitch along the bus WG
    p_smf_att_db: float = 0.14         # single-mode fiber attenuation

    @property
    def rin_lin(self) -> float:
        return 10.0 ** (self.rin_db_hz / 10.0)


# Network penalty per DPU organization (paper Table 1).
NETWORK_PENALTY_DB = {
    "heana": 1.8,
    "amw": 5.8,
    "maw": 4.8,
}


# ---------------------------------------------------------------------------
# Paper Table 3: accelerator peripheral power / latency / area
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Peripheral:
    power_mw: float
    latency_ns: float
    area_mm2: float


# Latencies given in "cycles" in Table 3 use the 1 GS/s symbol clock (1 ns).
PERIPHERALS = {
    "reduction_network": Peripheral(0.050, 3.125, 3.00e-5),
    "activation_unit": Peripheral(0.52, 0.78, 6.00e-5),
    "io_interface": Peripheral(140.18, 0.78, 2.44e-2),
    "pooling_unit": Peripheral(0.4, 3.125, 2.40e-4),
    "edram": Peripheral(41.1, 1.56, 1.66e-1),
    "bus": Peripheral(7.0, 5.0, 9.00e-3),
    "router": Peripheral(42.0, 2.0, 1.50e-2),
    "dac_baseline": Peripheral(12.5, 0.78, 2.50e-3),   # [41] — AMW/MAW DACs
    "dac_heana": Peripheral(26.0, 0.78, 6.00e-3),      # [18] — HEANA's 10GS/s DAC
}

EO_TUNING_POWER_W_PER_FSR = 80e-6     # electro-optic actuation
EO_TUNING_LATENCY_NS = 20.0
TO_TUNING_POWER_W_PER_FSR = 275e-3    # thermo-optic actuation (AMW/MAW weights)
TO_TUNING_LATENCY_NS = 4000.0         # 4 us

# BPD inverse bandwidth (1/symbol rate at 1 GS/s) and the TAOM max pulse
# width; their 10x ratio is what lets HEANA-OS accumulate 10 coherent pulses
# per cycle (paper §3.2.4 "Additional Benefits").
BPD_INV_BANDWIDTH_NS = 1.0
TAOM_MAX_PULSE_WIDTH_NS = 0.1
OS_COHERENT_PULSES_PER_CYCLE = int(BPD_INV_BANDWIDTH_NS / TAOM_MAX_PULSE_WIDTH_NS)

# BPCA capacitor-bank size for seamless IS/WS accumulation (paper §3.2.4).
BPCA_NUM_CAPACITORS = 4608


# ---------------------------------------------------------------------------
# Numerics configuration for the photonic GEMM simulation
# ---------------------------------------------------------------------------
class Backend(str, enum.Enum):
    EXACT = "exact"            # plain bf16/f32 XLA matmul (no photonics)
    INT_QUANT = "int_quant"    # plain integer quantization, no analog effects
    HEANA = "heana"            # TAOM + BPCA: analog carry, single ADC per output
    AMW = "amw"                # per-DPE-chunk ADC + digital reduction
    MAW = "maw"                # same accumulation policy as AMW, different N
    HEANA_AMW_BPCA = "amw_bpca"  # AMW array given HEANA's BPCA (Fig. 13/14)
    HEANA_MAW_BPCA = "maw_bpca"


class Dataflow(str, enum.Enum):
    WS = "ws"   # weight stationary
    IS = "is"   # input stationary
    OS = "os"   # output stationary


@dataclasses.dataclass(frozen=True)
class PhotonicConfig:
    """Configuration of the photonic numerics simulation.

    ``dpe_size`` is N — the optical dot-product width (number of wavelengths
    = TAOMs per DPE). It is normally derived from the scalability analysis
    (core.scalability.max_dpe_size) for the chosen backend/bits/data-rate.

    PhotonicConfig is the low-level carrier the kernels consume; the
    hardware identity it shares with the scheduler's AcceleratorConfig
    (backend, bits, N, data rate, dataflow, optics) should be DERIVED,
    not hand-set: build both from one ``core.hw.OperatingPoint``
    (``op.kernel_config()`` / ``op.accelerator_config()``).  The
    executor rejects a kernel config that disagrees with the plan's
    hardware (core.hw.check_kernel_plan_coherence).
    """
    backend: Backend = Backend.HEANA
    bits: int = 8                      # operand quantization bits B
    adc_bits: int = 8                  # output ADC resolution
    dpe_size: int = 83                 # N
    data_rate_gsps: float = 1.0        # DR
    dataflow: Dataflow = Dataflow.OS
    noise_enabled: bool = True
    # Optical power reaching each photodiode, per wavelength.  None => derive
    # from the link budget (Eq. 3) at the configured dpe_size.
    pd_power_dbm: Optional[float] = None
    optics: OpticalParams = dataclasses.field(default_factory=OpticalParams)
    # Round DPE chunks up to the MXU lane width inside the Pallas kernel.
    lane_pad: int = 128

    @property
    def qmax(self) -> int:
        return (1 << self.bits) - 1

    @property
    def adc_levels(self) -> int:
        return 1 << self.adc_bits

    def network_penalty_db(self) -> float:
        key = self.backend.value.replace("_bpca", "")
        return NETWORK_PENALTY_DB.get(key, NETWORK_PENALTY_DB["heana"])


# ---------------------------------------------------------------------------
# TPU v5e target constants for the roofline analysis (host accelerator)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TpuTarget:
    peak_flops_bf16: float = 197e12   # per chip
    hbm_bandwidth: float = 819e9      # bytes/s per chip
    ici_link_bandwidth: float = 50e9  # bytes/s per link
    hbm_bytes: float = 16 * 1024**3   # 16 GiB HBM per v5e chip
    vmem_bytes: float = 128 * 1024**2


TPU_V5E = TpuTarget()


def dbm_to_watt(dbm: float) -> float:
    return 1e-3 * 10.0 ** (dbm / 10.0)


def watt_to_dbm(watt: float) -> float:
    return 10.0 * math.log10(max(watt, 1e-30) / 1e-3)

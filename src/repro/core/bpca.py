"""BPCA — Balanced Photo-Charge Accumulator (paper §3.2.4).

A BPCA is a balanced photodiode pair (one diode per aggregation lane) feeding
a time-integrating receiver (TIR) with a bank of p capacitors:

  * per 1 ns cycle, the BPD integrates all optical pulses that arrive on the
    +/- lanes: the net photocharge is proportional to
    sum(through areas) - sum(drop areas), i.e. a signed dot-product psum of
    up to N (wavelengths) x 10 (OS coherent pulses) products;
  * the TIR accrues that charge on a selected capacitor, so psums belonging
    to the same output accumulate *in the analog domain* across cycles —
    no per-psum ADC, no psum buffer, no reduction network;
  * one ADC conversion happens per finished output value.

The capacitor-selection policy is dataflow dependent (OS: same capacitor for
consecutive cycles; IS/WS: rotate capacitors each cycle).  That policy has no
numerical effect (each output still sees exactly its own psums) but drives
the perf model's event counts.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import noise as noise_mod
from repro.core.types import (BPCA_NUM_CAPACITORS, OS_COHERENT_PULSES_PER_CYCLE,
                              Dataflow, PhotonicConfig, dbm_to_watt)


def detection_sigma_int(cfg: PhotonicConfig, p_pd_dbm: float) -> float:
    """Gaussian sigma of one BPD integration cycle, in integer product units.

    ``p_pd_dbm`` is the per-wavelength optical power at the photodiode (the
    link budget of Eq. 3 already contains the 10 log10(N) comb split).  The
    ENOB relation (Eqs. 1-2) demands that a single wavelength's full-scale
    product (qmax^2 integer units) be resolvable to B bits at that power, so
    the relative noise of one integration is 1/SNR of that full scale.  The
    noise is thermal-dominated at these powers, i.e. one draw per BPD
    integration cycle — NOT one per wavelength — which is why the N-way WDM
    sum rides the same noise floor (the BPCA's whole point).
    """
    sigma_rel = noise_mod.relative_noise_sigma(
        p_pd_dbm, cfg.data_rate_gsps, cfg.optics)
    full_scale = float(cfg.qmax) ** 2
    return full_scale * sigma_rel


def integrate_cycle(through: jnp.ndarray, drop: jnp.ndarray,
                    axis: int = -1) -> jnp.ndarray:
    """One BPD integration: net photocharge of a cycle's pulse ensemble."""
    return jnp.sum(through, axis=axis) - jnp.sum(drop, axis=axis)


def accumulate(psums: jnp.ndarray, *, cfg: PhotonicConfig,
               sigma_int: float = 0.0,
               key: Optional[jax.Array] = None,
               chunk_axis: int = -1) -> jnp.ndarray:
    """Analog temporal accumulation of per-cycle psums on one capacitor.

    psums: (..., n_chunks) — the per-cycle BPD outputs that belong to the
    same output value (OS dataflow keeps one capacitor selected for all of
    them).  Each cycle contributes an independent detection-noise draw, so
    the capacitor voltage carries noise sigma_int * sqrt(n_chunks).
    """
    total = jnp.sum(psums, axis=chunk_axis)
    if key is not None and sigma_int > 0.0:
        n_chunks = psums.shape[chunk_axis]
        total = total + sigma_int * jnp.sqrt(float(n_chunks)) * \
            jax.random.normal(key, total.shape, total.dtype)
    return total


def adc_readout(voltage: jnp.ndarray, adc_bits: int,
                full_scale: jnp.ndarray) -> jnp.ndarray:
    """Single ADC conversion of the accrued capacitor voltage.

    ``full_scale`` is the programmable-gain range (symmetric).  The ADC
    quantizes to 2^adc_bits uniform levels across [-FS, FS].
    """
    levels = (1 << adc_bits) - 1
    fs = jnp.maximum(full_scale, 1e-12)
    step = 2.0 * fs / levels
    return jnp.clip(jnp.round(voltage / step), -(levels // 2 + levels % 2),
                    levels // 2 + levels % 2) * step


def capacitor_schedule(dataflow: Dataflow, n_cycles: int,
                       outputs_per_cycle: int = 1) -> Tuple[int, int]:
    """(distinct capacitors used, ADC conversions) over an accumulation window.

    OS: one capacitor held for the whole window -> 1 conversion at the end.
    IS/WS: consecutive cycles belong to different outputs -> a capacitor per
    in-flight output (bounded by the bank size), still one conversion per
    finished output, but the bank must cover ``n_cycles`` in-flight outputs.
    """
    if dataflow == Dataflow.OS:
        return 1, 1
    caps = min(n_cycles * outputs_per_cycle, BPCA_NUM_CAPACITORS)
    return caps, n_cycles * outputs_per_cycle


def os_pulses_per_cycle() -> int:
    """OS dataflow: 10x coherent pulse accumulation headroom (paper §3.2.4)."""
    return OS_COHERENT_PULSES_PER_CYCLE

"""Pallas TPU kernel: flash attention (causal / sliding-window, fwd).

Online-softmax tiling for the training/prefill hot path: the (S, S) score
matrix never materializes — running (max, sum, weighted-V) stats live in
VMEM scratch while K/V stream through 128-wide blocks.

Grid: (BH, S/bq, S/bk) with the key axis innermost (sequential); scratch
(m, l, acc) persists across key steps for a fixed query tile.  Causal and
sliding-window masks are applied from global block offsets; fully-masked
key blocks contribute exp(-inf)=0 (correct, if not skipped — block-level
early-exit is a TPU grid limitation; the masking keeps it exact).

Layout contract: q/k/v are (BH, S, D) with heads pre-flattened into the
batch dim (GQA callers expand K/V per head first — same contract as the
model zoo's TP-aligned attention).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across 0.4/0.5; accept both.
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams",
                           getattr(pltpu, "TPUCompilerParams", None))

NEG_INF = -1.0e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int, bq: int, bk: int,
            nk: int, s_real: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                   # (bq, d)
    k = k_ref[0].astype(jnp.float32)                   # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qi = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kj = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    valid = kj < s_real            # padded keys are never attended
    if causal:
        valid &= kj <= qi
    if window:
        valid &= kj > qi - window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                                # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)                     # (bq, 1)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_fwd(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True, window: int = 0,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = False) -> jnp.ndarray:
    """q/k/v: (BH, S, D) -> (BH, S, D).  S padded to block multiples."""
    bh, s, d = q.shape
    scale = d ** -0.5
    bq = min(block_q, max(8, s))
    bk = min(block_k, max(8, s))
    sp_q = -(-s // bq) * bq
    sp_k = -(-s // bk) * bk
    sp = max(sp_q, sp_k)
    if sp != s:
        pad = ((0, 0), (0, sp - s), (0, 0))
        q = jnp.pad(q, pad)
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    nq, nk = sp // bq, sp // bk

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal,
                          window=window, bq=bq, bk=bk, nk=nk, s_real=s),
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sp, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, d), jnp.float32)],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :s, :]


def flash_attention_reference(q, k, v, *, causal: bool = True,
                              window: int = 0) -> jnp.ndarray:
    """Dense oracle: (BH, S, D) softmax attention with the same mask."""
    bh, s, d = q.shape
    scores = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * (d ** -0.5)
    qi = jnp.arange(s)[:, None]
    kj = jnp.arange(s)[None, :]
    valid = jnp.ones((s, s), bool)
    if causal:
        valid &= kj <= qi
    if window:
        valid &= kj > qi - window
    scores = jnp.where(valid[None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", probs,
                      v.astype(jnp.float32)).astype(q.dtype)

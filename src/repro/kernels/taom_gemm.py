"""Pallas TPU kernel: HEANA TAOM-array GEMM with BPCA accumulation policy.

Maps the paper's DPU dataflow onto the TPU memory hierarchy:

  * one DPE chunk (N = cfg.dpe_size wavelengths, zero-padded to the 128-wide
    MXU lane boundary) == one K-step of the kernel grid == one temporal fold;
  * the VMEM scratch accumulator == the BPCA capacitor: psums accrue across
    K-steps without leaving VMEM (HEANA policy: no per-chunk ADC, no psum
    buffer traffic — exactly the paper's point, restated for a TPU);
  * the AMW/MAW policy rounds every chunk psum through the ADC before the
    digital add, which the kernel reproduces in-loop (noise interacts with
    rounding, so it cannot be folded into the final draw);
  * detection noise is pre-sampled standard normal (PRNG stays outside the
    kernel), scaled by the link-budget sigma inside;
  * the ADC full scale is a *calibrated* scalar (programmable-gain setting),
    like real analog frontends — no data-dependent global max inside.

Zero-padding faithfulness: padded lanes contribute 0 to the integer psum and
do not move ADC rounding boundaries, so kernel results equal the pure-jnp
oracle (kernels/ref.py) that chunks at the exact dpe_size.

Grid: (M/bm, D/bd, C) with C innermost (sequential), so the accumulator
persists across chunk steps for a fixed output tile.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.types import Backend, PhotonicConfig

LANE = 128
SUBLANE = 8

# jax renamed TPUCompilerParams -> CompilerParams across 0.4/0.5; accept both.
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams",
                           getattr(pltpu, "TPUCompilerParams", None))


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def adc_round(v: jnp.ndarray, adc_bits: int, full_scale: float) -> jnp.ndarray:
    """Uniform mid-tread ADC over [-fs, fs] — mirrors core.bpca.adc_readout.

    ``full_scale`` is a PYTHON float (the calibrated PGA setting), so both
    ``step`` and its reciprocal are computed host-side in double precision
    and enter the traced program as multiply-by-constant only.  A traced
    ``v / step`` would be rewritten to a reciprocal multiply by XLA under
    whole-program jit but not eagerly, making compiled and eager forwards
    disagree by 1 ULP right at ADC rounding boundaries — this formulation
    is bit-identical under both, and kernels/ref.py shares this exact
    function so kernel and oracle cannot diverge either.
    """
    levels = (1 << adc_bits) - 1
    # Same degenerate-input floor as core.bpca.adc_readout: a zero/negative
    # programmed full scale clamps instead of dividing by zero.
    step = 2.0 * max(float(full_scale), 1e-12) / levels
    inv_step = 1.0 / step
    hi = levels // 2 + levels % 2
    return jnp.clip(jnp.round(v * inv_step), -hi, hi) * step


def calibrated_adc_fs(k: int, cfg: PhotonicConfig) -> float:
    """Analytic PGA calibration: ~4 sigma of a random-+/- integer dot walk."""
    qmax = float(cfg.qmax)
    return max(qmax ** 2 * math.sqrt(float(max(k, 1))) * (4.0 / 3.0), 1e-6)


def chunk_fs(cfg: PhotonicConfig) -> float:
    """Per-chunk ADC full scale for the AMW/MAW per-psum conversion."""
    qmax = float(cfg.qmax)
    return max(qmax ** 2 * math.sqrt(float(cfg.dpe_size)) * (4.0 / 3.0), 1e-6)


def _kernel_analog_carry(x_ref, w_ref, noise_ref, out_ref, acc_ref, *,
                         n_chunks: int, sigma: float, adc_bits: int,
                         adc_fs: float):
    """HEANA / *_bpca policy: analog accumulate, one noise draw + one ADC."""
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[0], w_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(c == n_chunks - 1)
    def _readout():
        acc = acc_ref[...]
        acc = acc + (sigma * math.sqrt(float(n_chunks))) * noise_ref[...]
        out_ref[...] = adc_round(acc, adc_bits, adc_fs)


def _kernel_chunk_adc(x_ref, w_ref, noise_ref, out_ref, acc_ref, *,
                      n_chunks: int, sigma: float, adc_bits: int,
                      fs_chunk: float):
    """AMW/MAW policy: per-chunk noise + ADC rounding, digital reduction."""
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    psum = jnp.dot(x_ref[0], w_ref[0], preferred_element_type=jnp.float32)
    acc_ref[...] += adc_round(psum + sigma * noise_ref[0], adc_bits, fs_chunk)

    @pl.when(c == n_chunks - 1)
    def _readout():
        out_ref[...] = acc_ref[...]   # chunk psums already quantized


def taom_gemm_quantized(xq: jnp.ndarray, wq: jnp.ndarray,
                        noise: jnp.ndarray, cfg: PhotonicConfig,
                        adc_fs: float,
                        *, block_m: int = 128, block_d: int = 128,
                        interpret: bool = False) -> jnp.ndarray:
    """Chunked photonic GEMM on pre-quantized integer-valued f32 operands.

    xq: (M, K); wq: (K, D) — integer-valued f32 (from core.taom.quantize).
    noise: standard normal — (M, D) for analog-carry backends,
    (C, M, D) for chunk-ADC backends (C = ceil(K / dpe_size)).
    Returns the integer-unit accumulation (M, D); caller applies scales.
    """
    m, k = xq.shape
    k2, d = wq.shape
    assert k == k2, (k, k2)
    n = cfg.dpe_size
    n_chunks = max(1, -(-k // n))

    # Lay K out as C lane-aligned chunk slots, zero-padded per slot.
    slot = _round_up(n, LANE)
    kpad = n_chunks * n - k
    xpad = jnp.pad(xq.astype(jnp.float32), ((0, 0), (0, kpad)))
    wpad = jnp.pad(wq.astype(jnp.float32), ((0, kpad), (0, 0)))
    xq_c = jnp.pad(xpad.reshape(m, n_chunks, n),
                   ((0, 0), (0, 0), (0, slot - n)))            # (M, C, slot)
    wq_c = jnp.pad(wpad.reshape(n_chunks, n, d),
                   ((0, 0), (0, slot - n), (0, 0)))            # (C, slot, D)

    # Pad M/D to block multiples.
    bm = min(block_m, _round_up(m, SUBLANE))
    bd = min(block_d, _round_up(d, LANE))
    mp, dp = _round_up(m, bm), _round_up(d, bd)
    xq_c = jnp.pad(xq_c, ((0, mp - m), (0, 0), (0, 0)))
    wq_c = jnp.pad(wq_c, ((0, 0), (0, 0), (0, dp - d)))
    x2 = xq_c.transpose(1, 0, 2)                               # (C, M, slot)

    chunk_adc = cfg.backend in (Backend.AMW, Backend.MAW)
    if chunk_adc:
        assert noise.shape == (n_chunks, m, d), noise.shape
        noise_p = jnp.pad(noise.astype(jnp.float32),
                          ((0, 0), (0, mp - m), (0, dp - d)))
        noise_spec = pl.BlockSpec((1, bm, bd), lambda i, j, c: (c, i, j))
    else:
        assert noise.shape == (m, d), noise.shape
        noise_p = jnp.pad(noise.astype(jnp.float32),
                          ((0, mp - m), (0, dp - d)))
        noise_spec = pl.BlockSpec((bm, bd), lambda i, j, c: (i, j))

    from repro.core.photonic_gemm import detection_sigma
    sigma = detection_sigma(cfg)

    if chunk_adc:
        kern = functools.partial(
            _kernel_chunk_adc, n_chunks=n_chunks, sigma=sigma,
            adc_bits=cfg.adc_bits, fs_chunk=chunk_fs(cfg))
    else:
        kern = functools.partial(
            _kernel_analog_carry, n_chunks=n_chunks, sigma=sigma,
            adc_bits=cfg.adc_bits, adc_fs=adc_fs)

    out = pl.pallas_call(
        kern,
        grid=(mp // bm, dp // bd, n_chunks),
        in_specs=[
            pl.BlockSpec((1, bm, slot), lambda i, j, c: (c, i, 0)),
            pl.BlockSpec((1, slot, bd), lambda i, j, c: (c, 0, j)),
            noise_spec,
        ],
        out_specs=pl.BlockSpec((bm, bd), lambda i, j, c: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, dp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bd), jnp.float32)],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x2, wq_c, noise_p)
    return out[:m, :d]

"""Public jit'd wrappers around the Pallas kernels, with dispatch + VJPs.

``photonic_matmul`` is what the model zoo calls: it quantizes, picks the
kernel or the pure-jnp oracle (kernels run in interpret mode on CPU), and
attaches the straight-through-estimator VJP so photonic numerics are
trainable.

``ssd_scan`` is the Mamba2 scan entry point: the Pallas kernel for the
serving/prefill hot path, and a differentiable chunked jnp implementation
(same math, jax.lax.scan over chunks) for training.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.photonic_gemm import sample_noise, noise_shape
from repro.core.taom import quantize
from repro.core.types import Backend, PhotonicConfig
from repro.kernels import ref as ref_mod
from repro.kernels import ssd_scan as ssd_kernel_mod
from repro.kernels import taom_gemm as taom_kernel_mod


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


# ---------------------------------------------------------------------------
# photonic_matmul
# ---------------------------------------------------------------------------
def _taom_forward(x2d: jnp.ndarray, w: jnp.ndarray, noise: jnp.ndarray,
                  cfg: PhotonicConfig, adc_fs: float, impl: str,
                  blocks: tuple) -> jnp.ndarray:
    f32 = jnp.float32
    xq, sx = quantize(x2d.astype(f32), cfg.bits, axis=None)
    wq, sw = quantize(w.astype(f32), cfg.bits, axis=0)
    if impl == "pallas":
        acc = taom_kernel_mod.taom_gemm_quantized(
            xq, wq, noise, cfg, adc_fs, block_m=blocks[0], block_d=blocks[1],
            interpret=_on_cpu())
    else:
        acc = ref_mod.taom_gemm_reference(xq, wq, noise, cfg, adc_fs)
    # Pin the rescale against XLA's algebraic simplifier: under
    # whole-program jit it reassociates this multiply chain with the ADC's
    # trailing *step (a splat constant) and with the quantize-scale chain,
    # shifting results by 1 ULP vs the op-by-op eager path — which then
    # crosses ADC rounding boundaries in later layers.  The barriers make
    # the compiled forward bit-identical to eager execution (the
    # executor's compiled-vs-eager contract; free at runtime).
    acc, sx, sw = jax.lax.optimization_barrier((acc, sx, sw))
    out = (acc * (sx * sw)).astype(x2d.dtype)
    return jax.lax.optimization_barrier(out)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _taom_ste(x2d, w, noise, cfg, adc_fs, impl, blocks):
    return _taom_forward(x2d, w, noise, cfg, adc_fs, impl, blocks)


def _taom_ste_fwd(x2d, w, noise, cfg, adc_fs, impl, blocks):
    return _taom_forward(x2d, w, noise, cfg, adc_fs, impl, blocks), (x2d, w)


def _taom_ste_bwd(cfg, adc_fs, impl, blocks, res, g):
    x2d, w = res
    return (g @ w.T).astype(x2d.dtype), (x2d.T @ g).astype(w.dtype), None


_taom_ste.defvjp(_taom_ste_fwd, _taom_ste_bwd)


def photonic_matmul(x: jnp.ndarray, w: jnp.ndarray, cfg: PhotonicConfig,
                    key: Optional[jax.Array] = None,
                    impl: str = "auto",
                    adc_fs: Optional[float] = None,
                    block_m: int = 128, block_d: int = 128) -> jnp.ndarray:
    """Photonic-numerics matmul: (..., K) @ (K, D) -> (..., D).

    Arbitrary leading batch dims fold into the GEMM M axis (the
    batch-serving shape: Toeplitz rows of every image concatenated), which
    is exactly how the perf model accounts batched CNN layers.

    impl: 'pallas' | 'ref' | 'auto' (pallas kernel, interpreted on CPU).
    adc_fs: calibrated PGA full scale; default = analytic calibration.
    block_m/block_d: kernel output-tile sizes (a LayerPlan's tiling choice
    from repro.exec.scheduler plugs in here; numerics are tile-invariant).

    jit-friendly: every branch here is on static config (cfg, impl, key
    being None), so the whole call traces into one compiled program —
    repro.exec.executor.forward_fn wraps an entire CNN of these in a
    single jax.jit.

    Noise contract: ``cfg.noise_enabled=True`` REQUIRES a PRNG key.  The
    old behavior (silently running noiseless when key=None) handed a user
    expecting noisy inference deterministic results with no signal that
    anything was off; now that combination raises — disable noise
    explicitly (cfg.noise_enabled=False) to run deterministically.  The
    EXACT backend is exempt: it bypasses the photonic pipeline entirely
    (no detectors exist to be noisy), so ``noise_enabled`` does not apply.
    """
    if cfg.backend == Backend.EXACT:
        return x @ w
    if cfg.noise_enabled and key is None:
        raise ValueError(
            "photonic_matmul: cfg.noise_enabled=True but key=None — "
            "detection noise needs a PRNG key.  Pass key=jax.random."
            "PRNGKey(...) for noisy inference, or set "
            "noise_enabled=False to run deterministically (the old "
            "behavior silently did the latter).")
    if impl == "auto":
        impl = "pallas"
    if adc_fs is None:
        adc_fs = taom_kernel_mod.calibrated_adc_fs(x.shape[-1], cfg)
    batch_shape = x.shape[:-1]
    x2d = x.reshape(-1, x.shape[-1])
    if key is not None and cfg.noise_enabled:
        noise = sample_noise(key, x2d.shape, w.shape, cfg)
    else:
        noise = jnp.zeros(noise_shape(x2d.shape, w.shape, cfg), jnp.float32)
    if cfg.backend in (Backend.AMW, Backend.MAW):
        noise = jnp.moveaxis(noise, -2, 0)   # (..., C, D) -> (C, M, D)
    out = _taom_ste(x2d, w, noise, cfg, float(adc_fs), impl,
                    (int(block_m), int(block_d)))
    return out.reshape(*batch_shape, w.shape[-1])


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------
def _ssd_chunked_jax(x, dt, a, b, c, chunk):
    """Differentiable chunked SSD — same decomposition as the kernel but
    with jax.lax.scan across chunks (used on the training path)."""
    bh, l, p = x.shape
    s = b.shape[-1]
    n_chunks = l // chunk
    f32 = jnp.float32
    xc = x.reshape(bh, n_chunks, chunk, p).astype(f32)
    dtc = dt.reshape(bh, n_chunks, chunk).astype(f32)
    bc = b.reshape(bh, n_chunks, chunk, s).astype(f32)
    cc = c.reshape(bh, n_chunks, chunk, s).astype(f32)
    a = a.astype(f32)

    da = dtc * a[:, None, None]                       # (BH, C, Q)
    cum = jnp.cumsum(da, axis=-1)
    row = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    causal = row >= col

    seg = cum[..., :, None] - cum[..., None, :]       # (BH, C, Q, Q)
    lmat = jnp.where(causal, jnp.exp(seg) * dtc[..., None, :], 0.0)
    scores = jnp.einsum("zkqs,zkts->zkqt", cc, bc) * lmat
    y_intra = jnp.einsum("zkqt,zktp->zkqp", scores, xc)

    # Per-chunk state contribution and decay.
    wgt = jnp.exp(cum[..., -1:] - cum) * dtc          # (BH, C, Q)
    chunk_states = jnp.einsum("zkq,zkqp,zkqs->zkps", wgt, xc, bc)
    chunk_decay = jnp.exp(cum[..., -1])               # (BH, C)

    def step(state, inp):
        cs, cd = inp                                   # (BH,P,S), (BH,)
        new = state * cd[:, None, None] + cs
        return new, state                              # emit state *before*

    init = jnp.zeros((bh, p, s), f32)
    final_state, prev_states = jax.lax.scan(
        step, init, (jnp.moveaxis(chunk_states, 1, 0),
                     jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)      # (BH, C, P, S)

    y_inter = jnp.einsum("zkqs,zkps->zkqp", cc, prev_states) * \
        jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(bh, l, p).astype(x.dtype)
    return y, final_state


def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
             b: jnp.ndarray, c: jnp.ndarray, *, chunk: int = 128,
             impl: str = "auto") -> tuple[jnp.ndarray, jnp.ndarray]:
    """Mamba2 SSD scan (flattened batch*heads layout; see ref.py for shapes).

    Pads L up to a chunk multiple internally.  impl: 'pallas' | 'jax' |
    'auto' ('jax' — differentiable — unless explicitly asked for pallas).
    """
    bh, l, p = x.shape
    lpad = (-l) % chunk
    if lpad:
        x = jnp.pad(x, ((0, 0), (0, lpad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, lpad)))
        b = jnp.pad(b, ((0, 0), (0, lpad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, lpad), (0, 0)))
    if impl == "auto":
        impl = "jax"
    if impl == "pallas":
        y, state = ssd_kernel_mod.ssd_scan_chunked(
            x, dt, a, b, c, chunk=chunk, interpret=_on_cpu())
    else:
        y, state = _ssd_chunked_jax(x, dt, a, b, c, chunk)
    return y[:, :l], state


def ssd_decode_step(state: jnp.ndarray, x_t: jnp.ndarray, dt_t: jnp.ndarray,
                    a: jnp.ndarray, b_t: jnp.ndarray, c_t: jnp.ndarray
                    ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Single-token SSD recurrence for serving.

    state: (BH, P, S); x_t: (BH, P); dt_t: (BH,); a: (BH,);
    b_t, c_t: (BH, S).  Returns (y_t: (BH, P), new_state).
    """
    decay = jnp.exp(dt_t * a)                          # (BH,)
    upd = (dt_t[:, None] * x_t)[:, :, None] * b_t[:, None, :]
    new_state = decay[:, None, None] * state + upd
    y = jnp.einsum("zps,zs->zp", new_state, c_t)
    return y.astype(x_t.dtype), new_state

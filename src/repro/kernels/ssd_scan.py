"""Pallas TPU kernel: Mamba2 SSD (state-space duality) chunked scan.

The SSD decomposition splits the sequence into chunks of length Q:

  * intra-chunk: a (Q, Q) causal "attention-like" block — MXU-friendly
    matmuls (C B^T masked by the decay kernel L);
  * inter-chunk: a (P, S) running state carried across chunks — lives in
    VMEM scratch, updated once per chunk step (the sequential recurrence is
    hoisted from per-token to per-chunk, exactly the paper's trick in
    arXiv:2405.21060, adapted to TPU: chunk length 128 keeps both matmul
    operands MXU-aligned while the state never leaves VMEM).

Grid: (BH, L/Q) with the chunk axis innermost/sequential. Head groups are
expanded to per-head B/C *outside* the kernel (G -> H), keeping the body a
dense per-head computation.

Decay exponents are always <= 0 (dt > 0, a < 0), so every exp() here is in
(0, 1] — numerically safe in f32 without max-subtraction tricks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across 0.4/0.5; accept both.
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams",
                           getattr(pltpu, "TPUCompilerParams", None))


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_out_ref,
                state_ref, *, n_chunks: int):
    c_idx = pl.program_id(1)

    @pl.when(c_idx == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)            # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)          # (Q,)
    a = a_ref[0, 0].astype(jnp.float32)         # scalar
    b = b_ref[0].astype(jnp.float32)            # (Q, S)
    c = c_ref[0].astype(jnp.float32)            # (Q, S)

    da = dt * a                                  # (Q,) each <= 0
    cum = jnp.cumsum(da)                         # (Q,) decreasing
    q = x.shape[0]

    # Intra-chunk: scores[t, s] = (c_t . b_s) * exp(cum_t - cum_s) * dt_s,
    # causal (s <= t).
    seg = cum[:, None] - cum[None, :]            # (Q, Q)
    row = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    lmat = jnp.where(row >= col, jnp.exp(seg) * dt[None, :], 0.0)
    scores = jnp.dot(c, b.T, preferred_element_type=jnp.float32) * lmat
    y_intra = jnp.dot(scores, x, preferred_element_type=jnp.float32)

    # Inter-chunk: contribution of the carried state.
    state = state_ref[...]                       # (P, S)
    y_inter = jnp.dot(c, state.T,
                      preferred_element_type=jnp.float32) * \
        jnp.exp(cum)[:, None]                    # (Q, P)

    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # State update: state' = exp(cum_end) state + sum_s exp(cum_end - cum_s)
    #                         dt_s x_s (outer) b_s
    carry_decay = jnp.exp(cum[-1])
    w = jnp.exp(cum[-1] - cum) * dt              # (Q,)
    state_ref[...] = carry_decay * state + jnp.dot(
        (w[:, None] * x).T, b, preferred_element_type=jnp.float32)

    @pl.when(c_idx == n_chunks - 1)
    def _final():
        state_out_ref[0] = state_ref[...].astype(state_out_ref.dtype)


def ssd_scan_chunked(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
                     b: jnp.ndarray, c: jnp.ndarray, *, chunk: int = 128,
                     interpret: bool = False
                     ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """SSD scan over flattened (batch*head) sequences.

    x: (BH, L, P); dt: (BH, L); a: (BH,); b, c: (BH, L, S), already
    head-expanded.  L must be divisible by ``chunk`` (caller pads).
    Returns (y: (BH, L, P), final_state: (BH, P, S)).
    """
    bh, l, p = x.shape
    s = b.shape[-1]
    assert l % chunk == 0, (l, chunk)
    n_chunks = l // chunk
    a2 = a.reshape(bh, 1).astype(jnp.float32)

    y, state = pl.pallas_call(
        functools.partial(_ssd_kernel, n_chunks=n_chunks),
        grid=(bh, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, k: (i, k, 0)),
            pl.BlockSpec((1, chunk), lambda i, k: (i, k)),
            pl.BlockSpec((1, 1), lambda i, k: (i, 0)),
            pl.BlockSpec((1, chunk, s), lambda i, k: (i, k, 0)),
            pl.BlockSpec((1, chunk, s), lambda i, k: (i, k, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, k: (i, k, 0)),
            pl.BlockSpec((1, p, s), lambda i, k: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, l, p), x.dtype),
            jax.ShapeDtypeStruct((bh, p, s), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, s), jnp.float32)],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, a2, b, c)
    return y, state

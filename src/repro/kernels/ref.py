"""Pure-jnp oracles for the Pallas kernels.

These are the ground truth the kernels are swept against in
tests/test_kernels.py (interpret mode on CPU).  The taom_gemm oracle shares
its math with core.photonic_gemm but takes the *same explicit inputs* as the
kernel (pre-quantized operands, pre-sampled noise, calibrated ADC scale) so
comparisons are apples-to-apples.
"""
from __future__ import annotations

import math

import jax.numpy as jnp

from repro.core.photonic_gemm import detection_sigma
from repro.core.types import Backend, PhotonicConfig
# The ADC model is shared with the kernel, python-float full scale and
# all: both sides compute the same host-side step/reciprocal constants, so
# the oracle cannot diverge from the kernel by a compile-mode ULP (see
# adc_round's docstring).
from repro.kernels.taom_gemm import adc_round, chunk_fs


def taom_gemm_reference(xq: jnp.ndarray, wq: jnp.ndarray,
                        noise: jnp.ndarray, cfg: PhotonicConfig,
                        adc_fs: float) -> jnp.ndarray:
    """Oracle for kernels.taom_gemm.taom_gemm_quantized.

    Chunks at the exact dpe_size (no lane padding — zero-padding in the
    kernel is a no-op by construction, which this oracle verifies).
    """
    m, k = xq.shape
    _, d = wq.shape
    n = cfg.dpe_size
    n_chunks = max(1, -(-k // n))
    kp = n_chunks * n - k
    x = jnp.pad(xq.astype(jnp.float32), ((0, 0), (0, kp)))
    w = jnp.pad(wq.astype(jnp.float32), ((0, kp), (0, 0)))
    xc = x.reshape(m, n_chunks, n)
    wc = w.reshape(n_chunks, n, d)
    psums = jnp.einsum("mcn,cnd->cmd", xc, wc,
                       preferred_element_type=jnp.float32)    # (C, M, D)
    sigma = detection_sigma(cfg)
    if cfg.backend in (Backend.AMW, Backend.MAW):
        assert noise.shape == (n_chunks, m, d)
        noisy = psums + sigma * noise
        quant = adc_round(noisy, cfg.adc_bits, chunk_fs(cfg))
        return jnp.sum(quant, axis=0)
    assert noise.shape == (m, d)
    acc = jnp.sum(psums, axis=0)
    acc = acc + sigma * math.sqrt(float(n_chunks)) * noise
    return adc_round(acc, cfg.adc_bits, float(adc_fs))


def ssd_scan_reference(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
                       b: jnp.ndarray, c: jnp.ndarray,
                       initial_state: jnp.ndarray | None = None
                       ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Naive sequential Mamba2/SSD recurrence — oracle for kernels.ssd_scan.

    Shapes (single batch element):
      x:  (L, H, P)   input per head (P = head dim)
      dt: (L, H)      softplus-activated step sizes (>0)
      a:  (H,)        negative state decay rate (A = -exp(a_log) outside)
      b:  (L, G, S)   input->state projection (G state groups, S state dim)
      c:  (L, G, S)   state->output projection
    Heads are grouped: head h uses group g = h // (H // G).
    Returns (y: (L, H, P), final_state: (H, P, S)).
    """
    l, h, p = x.shape
    g, s = b.shape[1], b.shape[2]
    heads_per_group = h // g
    state = (jnp.zeros((h, p, s), jnp.float32) if initial_state is None
             else initial_state.astype(jnp.float32))
    ys = []
    for t in range(l):
        dt_t = dt[t]                                   # (H,)
        decay = jnp.exp(dt_t * a)                      # (H,)  a < 0
        bg = b[t]                                      # (G, S)
        cg = c[t]                                      # (G, S)
        b_h = jnp.repeat(bg, heads_per_group, axis=0)  # (H, S)
        c_h = jnp.repeat(cg, heads_per_group, axis=0)  # (H, S)
        # state update: state = decay * state + dt * x_t (outer) b_t
        upd = (dt_t[:, None] * x[t])[:, :, None] * b_h[:, None, :]
        state = decay[:, None, None] * state + upd
        ys.append(jnp.einsum("hps,hs->hp", state, c_h))
    return jnp.stack(ys).astype(x.dtype), state

"""Photonic execution engine: dataflow auto-scheduler + Pallas CNN executor.

The three layers (see ISSUE 1 / paper §4, §6.3):

  * scheduler — per-layer {OS, IS, WS} x tiling search over the
    event-driven perf model, with a content-addressed plan cache;
  * executor  — runs each planned GEMM through the Pallas TAOM kernel
    (quantize -> kernel -> rescale), batch folded into the GEMM M axis,
    noise keys threaded per layer; the serving hot path is a jit-compiled
    pure forward (compiled_forward / forward_fn) with the plan's tilings
    baked in as static arguments and zero per-layer host syncs;
  * report    — modeled latency/energy aggregated next to executed
    numerics, feeding benchmarks/autoflow.py, benchmarks/throughput.py
    and examples;

Hardware is specified ONCE: pass a core.hw.OperatingPoint wherever an
AcceleratorConfig is accepted — the plan embeds it (plan v4), the
executor holds the kernel PhotonicConfig coherent with it, and
executed-trace energy (ExecutionResult.energy(), LayerTrace.
executed_energy_j, ServingEngine.stats() joules/watts) is charged from
it via the same perf-model event accounting as the analytic figures
(ISSUE 5).
  * serving   — batched multi-device serving engine over the compiled
    forward: power-of-two batch buckets (one pre-traced plan each, AOT
    warmup), a thread-safe micro-batcher coalescing single-image
    requests, a data-parallel path sharding the bucketed batch over
    jax.devices() (bitwise equal to single-device, noise off), and
    p50/p99/throughput/padding metrics (ISSUE 4).

Networks are described by the lowering IR (models.lowering.OpGraph —
stride/padding convs, depthwise convs, pooling, residual adds, concats,
channel shuffles); models.zoo_cnn registers reduced-scale runnable
variants of the paper's four evaluation CNNs on it (ISSUE 3), and the
legacy flat LoweredLayer tuples keep working.
"""
from repro.exec.executor import (ExecutionResult, LayerTrace,
                                 compile_cache_stats, compiled_forward,
                                 execute_cnn, forward_fn,
                                 lowering_fingerprint, plan_for_network,
                                 reference_forward, trace_count)
from repro.exec.plan_cache import GLOBAL_PLAN_CACHE, PlanCache, fingerprint
from repro.exec.report import (energy_summary, execution_summary,
                               graph_summary, plan_summary, plan_table,
                               plan_vs_fixed, render_report, save_summary,
                               serving_summary, throughput_summary)
from repro.exec.scheduler import (CnnPlan, FrozenCandidates, LayerPlan,
                                  TileChoice, plan_layer, schedule_buckets,
                                  schedule_cnn)
from repro.exec.serving import (MicroBatcher, ServingEngine, bucket_for,
                                power_of_two_buckets)

__all__ = [
    "CnnPlan", "FrozenCandidates", "LayerPlan", "TileChoice", "plan_layer",
    "schedule_cnn", "schedule_buckets",
    "ServingEngine", "MicroBatcher", "power_of_two_buckets", "bucket_for",
    "serving_summary",
    "PlanCache", "GLOBAL_PLAN_CACHE", "fingerprint",
    "ExecutionResult", "LayerTrace", "execute_cnn", "plan_for_network",
    "reference_forward", "compiled_forward", "forward_fn", "trace_count",
    "compile_cache_stats", "lowering_fingerprint",
    "plan_summary", "plan_table", "plan_vs_fixed", "execution_summary",
    "graph_summary", "render_report", "save_summary", "throughput_summary",
    "energy_summary",
]

"""Photonic execution engine: dataflow auto-scheduler + Pallas CNN executor.

The three layers (see ISSUE 1 / paper §4, §6.3):

  * scheduler — per-layer {OS, IS, WS} x tiling search over the
    event-driven perf model, with a content-addressed plan cache;
  * executor  — runs each planned GEMM through the Pallas TAOM kernel
    (quantize -> kernel -> rescale), batch folded into the GEMM M axis,
    noise keys threaded per layer;
  * report    — modeled latency/energy aggregated next to executed
    numerics, feeding benchmarks/autoflow.py and examples.
"""
from repro.exec.executor import (ExecutionResult, LayerTrace, execute_cnn,
                                 plan_for_network, reference_forward)
from repro.exec.plan_cache import GLOBAL_PLAN_CACHE, PlanCache, fingerprint
from repro.exec.report import (execution_summary, plan_summary, plan_table,
                               plan_vs_fixed, render_report, save_summary)
from repro.exec.scheduler import (CnnPlan, LayerPlan, TileChoice, plan_layer,
                                  schedule_cnn)

__all__ = [
    "CnnPlan", "LayerPlan", "TileChoice", "plan_layer", "schedule_cnn",
    "PlanCache", "GLOBAL_PLAN_CACHE", "fingerprint",
    "ExecutionResult", "LayerTrace", "execute_cnn", "plan_for_network",
    "reference_forward",
    "plan_summary", "plan_table", "plan_vs_fixed", "execution_summary",
    "render_report", "save_summary",
]

"""Content-addressed plan cache for the dataflow auto-scheduler.

Planning a layer means sweeping {OS, IS, WS} x tiling through the
event-driven perf model.  CNNs repeat shapes heavily (ResNet50's 16
bottlenecks contribute ~4 distinct GEMM shapes), and a serving fleet
re-plans the same (shape, accelerator) pairs on every process start — so
plans are cached under a digest of *what determines them*: the GEMM shape,
the accelerator configuration, and the search objective.  Nothing else
(layer names, wall-clock, process) enters the key, which makes the cache
safely shareable across CNNs, sessions, and hosts.

The store is in-memory with optional JSON persistence (``dump``/``load``)
so a warmed cache can ship with a deployment.  Values are JSON-safe plan
dicts (the scheduler owns (de)serialization of its LayerPlan type).

Deployment hardening (long-lived serving processes):

  * ``dump`` is atomic — the JSON is written to a sibling temp file and
    ``os.replace``d into place, so a crash mid-write can never leave a
    truncated file that poisons every subsequent ``load``;
  * ``load`` is tolerant — an unreadable/corrupt file loads 0 entries
    (with a warning) instead of raising mid-merge, and individual
    malformed entries are skipped rather than admitted;
  * the store is LRU-bounded (``max_entries``) so a process that plans an
    unbounded stream of shapes cannot grow the cache without limit.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
import threading
import warnings
from collections import OrderedDict
from typing import Dict, Optional

# Serialized plan-entry format version.  The scheduler stamps every
# persisted entry with it (and bakes it into the content-addressed key),
# so a dump written by an older scheduler cleanly invalidates: ``load``
# skips foreign-version entries instead of admitting plans whose layout
# or semantics have since changed.  v4: plans embed the hardware
# operating point (repro.core.hw.OperatingPoint) — pre-v4 entries carry
# no version stamp at all and are likewise skipped.
PLAN_FORMAT_VERSION = 4

# Keys every serialized LayerPlan dict must carry to be admitted by
# ``load`` (mirrors scheduler._plan_to_dict's output).
_REQUIRED_ENTRY_KEYS = frozenset(
    {"c", "k", "d", "count", "dataflow", "latency_s", "energy_j",
     "candidates", "tile", "cache_key", "plan_version"})

# Default bound: comfortably above the whole CNN zoo x backends x batches
# grid (~a few hundred distinct shapes) while capping a runaway stream.
DEFAULT_MAX_ENTRIES = 4096


def fingerprint(payload: dict) -> str:
    """Content address of a planning problem: sha256 of canonical JSON."""
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode("utf-8")).hexdigest()


def _entry_ok(key, value) -> bool:
    """Is (key, value) a well-formed, current-version serialized entry?"""
    return (isinstance(key, str) and isinstance(value, dict)
            and _REQUIRED_ENTRY_KEYS.issubset(value.keys())
            and value.get("plan_version") == PLAN_FORMAT_VERSION
            and isinstance(value.get("tile"), dict)
            and isinstance(value.get("candidates"), dict))


class PlanCache:
    """Thread-safe, LRU-bounded, content-addressed store of layer plans."""

    def __init__(self, max_entries: int = DEFAULT_MAX_ENTRIES) -> None:
        if max_entries < 1:
            raise ValueError(f"max_entries must be >= 1, got {max_entries}")
        self._store: "OrderedDict[str, dict]" = OrderedDict()
        self._lock = threading.Lock()
        self.max_entries = max_entries
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._store)

    def get(self, key: str) -> Optional[dict]:
        with self._lock:
            val = self._store.get(key)
            if val is None:
                self.misses += 1
                return None
            self._store.move_to_end(key)        # LRU touch
            self.hits += 1
            return dict(val)

    def put(self, key: str, value: dict) -> None:
        with self._lock:
            self._store[key] = dict(value)
            self._store.move_to_end(key)
            while len(self._store) > self.max_entries:
                self._store.popitem(last=False)  # evict least-recently used
                self.evictions += 1

    def stats(self) -> dict:
        with self._lock:
            return {"entries": len(self._store), "hits": self.hits,
                    "misses": self.misses, "evictions": self.evictions,
                    "max_entries": self.max_entries}

    def clear(self) -> None:
        with self._lock:
            self._store.clear()
            self.hits = 0
            self.misses = 0
            self.evictions = 0

    # -- persistence --------------------------------------------------------
    def dump(self, path: str) -> None:
        """Atomically persist the store as JSON (write temp + os.replace).

        Entries are written in LRU order (least- to most-recently used) —
        the OrderedDict's own iteration order.  ``sort_keys`` must NOT be
        used on the top level: sha256 keys sort lexicographically, which
        would scramble recency and make ``load``'s "keep only the last
        ``max_entries``" trim an arbitrary subset instead of the MRU set
        it promises.  (Values are plan dicts; their key order is
        irrelevant.)
        """
        with self._lock:
            blob = json.dumps(self._store)
        directory = os.path.dirname(os.path.abspath(path))
        fd, tmp = tempfile.mkstemp(prefix=".plan_cache.", suffix=".tmp",
                                   dir=directory)
        try:
            with os.fdopen(fd, "w") as fh:
                fh.write(blob)
            os.replace(tmp, path)               # atomic on POSIX and NT
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def load(self, path: str) -> int:
        """Merge well-formed entries from ``path``; returns how many were
        actually RETAINED (a file larger than ``max_entries`` merges only
        its most-recent fit, with a warning — the return value never
        overstates what survived).

        Never raises on a corrupt or truncated file: a warmed-cache
        deployment must survive a bad artifact (it only costs re-planning).
        Malformed individual entries are skipped, valid ones still merge.
        """
        try:
            with open(path) as fh:
                entries = json.load(fh)
        except (OSError, ValueError) as exc:
            warnings.warn(f"plan cache {path!r} unreadable, loading 0 "
                          f"entries: {exc}", RuntimeWarning, stacklevel=2)
            return 0
        if not isinstance(entries, dict):
            warnings.warn(f"plan cache {path!r} is not a JSON object, "
                          f"loading 0 entries", RuntimeWarning, stacklevel=2)
            return 0
        good: Dict[str, dict] = {k: v for k, v in entries.items()
                                 if _entry_ok(k, v)}
        stale = sum(1 for v in entries.values()
                    if isinstance(v, dict)
                    and v.get("plan_version") != PLAN_FORMAT_VERSION)
        skipped = len(entries) - len(good) - stale
        if stale:
            warnings.warn(
                f"plan cache {path!r}: skipped {stale} entries from an "
                f"older plan format (current v{PLAN_FORMAT_VERSION}) — "
                f"they will be re-planned and re-persisted on next dump",
                RuntimeWarning, stacklevel=2)
        if skipped:
            warnings.warn(f"plan cache {path!r}: skipped {skipped} "
                          f"malformed entries", RuntimeWarning, stacklevel=2)
        if len(good) > self.max_entries:
            warnings.warn(
                f"plan cache {path!r} holds {len(good)} entries but "
                f"max_entries={self.max_entries}; merging only the last "
                f"{self.max_entries}", RuntimeWarning, stacklevel=2)
            good = dict(list(good.items())[-self.max_entries:])
        for key, value in good.items():
            self.put(key, value)
        return len(good)


# Process-wide default cache (schedule_cnn uses it unless handed another).
# LRU-bounded so a long-lived serving process can't grow it without limit.
GLOBAL_PLAN_CACHE = PlanCache()

"""Content-addressed plan cache for the dataflow auto-scheduler.

Planning a layer means sweeping {OS, IS, WS} x tiling through the
event-driven perf model.  CNNs repeat shapes heavily (ResNet50's 16
bottlenecks contribute ~4 distinct GEMM shapes), and a serving fleet
re-plans the same (shape, accelerator) pairs on every process start — so
plans are cached under a digest of *what determines them*: the GEMM shape,
the accelerator configuration, and the search objective.  Nothing else
(layer names, wall-clock, process) enters the key, which makes the cache
safely shareable across CNNs, sessions, and hosts.

The store is in-memory with optional JSON persistence (``dump``/``load``)
so a warmed cache can ship with a deployment.  Values are JSON-safe plan
dicts (the scheduler owns (de)serialization of its LayerPlan type).
"""
from __future__ import annotations

import hashlib
import json
import threading
from typing import Dict, Optional


def fingerprint(payload: dict) -> str:
    """Content address of a planning problem: sha256 of canonical JSON."""
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode("utf-8")).hexdigest()


class PlanCache:
    """Thread-safe content-addressed store of solved layer plans."""

    def __init__(self) -> None:
        self._store: Dict[str, dict] = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._store)

    def get(self, key: str) -> Optional[dict]:
        with self._lock:
            val = self._store.get(key)
            if val is None:
                self.misses += 1
                return None
            self.hits += 1
            return dict(val)

    def put(self, key: str, value: dict) -> None:
        with self._lock:
            self._store[key] = dict(value)

    def stats(self) -> dict:
        with self._lock:
            return {"entries": len(self._store), "hits": self.hits,
                    "misses": self.misses}

    def clear(self) -> None:
        with self._lock:
            self._store.clear()
            self.hits = 0
            self.misses = 0

    # -- persistence --------------------------------------------------------
    def dump(self, path: str) -> None:
        with self._lock:
            blob = json.dumps(self._store, sort_keys=True)
        with open(path, "w") as fh:
            fh.write(blob)

    def load(self, path: str) -> int:
        """Merge entries from ``path``; returns how many were loaded."""
        with open(path) as fh:
            entries = json.load(fh)
        with self._lock:
            self._store.update(entries)
        return len(entries)


# Process-wide default cache (schedule_cnn uses it unless handed another).
GLOBAL_PLAN_CACHE = PlanCache()

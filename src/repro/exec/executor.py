"""End-to-end CNN executor over the Pallas TAOM kernel.

Runs a *runnable* GEMM-lowered CNN (a models.lowering.OpGraph — stride/
padding convs, depthwise convs, pooling, residuals, concats, shuffles —
or a legacy flat models.cnn.LoweredLayer tuple, + params dict)
image-batch in, logits out, with every GEMM executed by
kernels.ops.photonic_matmul: quantize -> TAOM kernel (Pallas; interpreted
on CPU) -> rescale.  This turns the repo's analytic per-figure scripts
into an actual inference engine producing real activations — the
reduced-scale variants of the paper's four evaluation CNNs
(models.zoo_cnn.ZOO) run through here.

Batching follows the paper's Toeplitz accounting: the image batch folds
into the GEMM M axis (all images' im2col rows concatenated), which is both
the batch-serving shape and what core.perf_model charges for batched
layers.  Detection-noise keys are threaded per layer — fold_in(key,
layer_index) — so every layer draws independent noise and runs are
reproducible from one root key.

The executor consumes a CnnPlan from exec.scheduler: each layer's GEMM
uses the plan's kernel tiling (block_m, block_d).  The plan's *dataflow*
choice changes scheduling (latency/energy in the report), never numerics —
with noise disabled the executed network equals the pure-jnp reference
(kernels/ref.py) bit-exactly, whatever the plan says (tests pin this).

Hot path (the serving contract HEANA's buffer-less pitch implies — the
loop must never stall on the host):

  * ``forward_fn`` is a pure jax.jit function of (params, x, key) with the
    lowering, plan (tilings), cfg and impl baked in as *static* arguments;
    one warm call = one cached executable, zero retracing, zero host syncs;
  * per-layer numerics fingerprints (mean |activation|) are computed
    on-device inside the compiled program and returned as ONE stacked
    array; ``ExecutionResult.traces`` materializes them lazily, only when
    a caller actually asks — never as per-layer ``float()`` syncs in the
    loop;
  * ``compiled_forward`` memoizes the jit wrapper under (lowering
    fingerprint, plan cache keys, cfg, impl); jax.jit's own cache then
    keys the executable on the batch shape/dtype — repeated serving calls
    hit a traced executable;
  * ``execute_cnn`` stays the thin eager-looking wrapper with today's
    ExecutionResult API (``compiled=False`` opts back into the eager
    op-by-op path, kept for the throughput benchmark's baseline).
"""
from __future__ import annotations

import dataclasses
import functools
import threading
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp

from repro.core import dataflow as df
from repro.core import hw
from repro.core.types import PhotonicConfig
from repro.exec import plan_cache as pc
from repro.exec.scheduler import CnnPlan, LayerPlan
from repro.kernels import ops
from repro.models import cnn as cnn_mod
from repro.models import lowering as lw

_LOWERING_FP_VERSION = 2

#: A runnable network description: op-graph IR or legacy flat tuple.
Lowering = Union[lw.OpGraph, Sequence[cnn_mod.LoweredLayer]]


@dataclasses.dataclass
class LayerTrace:
    """What actually ran for one layer (executed next to modeled)."""
    name: str
    m: int                 # executed GEMM rows (batch folded in)
    k: int
    d: int
    dataflow: str
    block_m: int
    block_d: int
    latency_s: float       # modeled (from the plan)
    energy_j: float        # modeled (from the plan)
    out_mean_abs: float    # executed-numerics fingerprint
    # Executed-trace energy accounting (PR 5): the temporal folds the
    # kernel actually ran (the tile's K chunking), the hardware ADC
    # conversions the executed schedule implies, and the per-layer energy
    # charged from those executed counts via core.energy — one
    # core.perf_model.gemm_cost accounting path for modeled AND executed.
    n_chunks: int = 0
    adc_conversions: int = 0
    executed_energy_j: float = 0.0


@dataclasses.dataclass
class ExecutionResult:
    """Logits + plan + lazily materialized per-layer traces.

    ``fingerprints`` is the (n_layers,) device array of mean-|activation|
    per layer, computed inside the compiled forward.  ``traces`` converts
    it to floats on FIRST ACCESS — a serving loop that never reads traces
    never syncs on them.
    """
    logits: jnp.ndarray
    plan: CnnPlan
    fingerprints: jnp.ndarray
    activations: Optional[List[jnp.ndarray]] = None
    _traces: Optional[List[LayerTrace]] = dataclasses.field(
        default=None, repr=False)
    _energy: Optional[hw.TraceEnergy] = dataclasses.field(
        default=None, repr=False)

    @property
    def traces(self) -> List[LayerTrace]:
        if self._traces is None:
            fp = [float(v) for v in jax.device_get(self.fingerprints)]
            energy = self.energy()
            acc = self.plan.acc
            self._traces = []
            for i, p in enumerate(self.plan.layers):
                # "what actually ran": depthwise layers execute as ONE
                # fused block-diagonal GEMM, so trace the executed
                # (M, K, D) — LayerGemm.executed owns the convention —
                # consistent with the tile the scheduler sized for it.
                m, k, d = lw.LayerGemm(p.name, p.c, p.k, p.d,
                                       p.count).executed
                # Hardware event counts behind the executed energy: ADCs
                # are charged on the paper's grouped accounting (the
                # fused depthwise GEMM is a host-simulation device, its
                # structural zeros are not photonic work).
                sch = df.schedule(df.GemmShape(p.c, p.k, p.d), p.dataflow,
                                  acc.n, acc.m, acc.has_bpca)
                self._traces.append(LayerTrace(
                    name=p.name, m=m, k=k, d=d,
                    dataflow=p.dataflow.value, block_m=p.tile.block_m,
                    block_d=p.tile.block_d, latency_s=p.latency_s,
                    energy_j=p.energy_j, out_mean_abs=fp[i],
                    n_chunks=p.tile.n_chunks,
                    adc_conversions=sch.adc_conversions * p.count,
                    executed_energy_j=energy.per_layer_j[i]))
        return self._traces

    @property
    def modeled_latency_s(self) -> float:
        return self.plan.latency_s

    @property
    def modeled_fps(self) -> float:
        return self.plan.fps

    def energy(self) -> hw.TraceEnergy:
        """Executed-trace energy/FPS accounting of this run (memoized).

        Computed host-side from the plan's executed layer list via
        core.hw.trace_energy — NO device sync (unlike ``traces``, which
        materializes the numerics fingerprints): a serving loop can read
        joules without stalling the stream.
        """
        if self._energy is None:
            self._energy = hw.trace_energy(self.plan)
        return self._energy

    @property
    def executed_energy_j(self) -> float:
        """Total executed-trace energy for this batch (static incl.)."""
        return self.energy().energy_j

    @property
    def executed_fps_per_watt(self) -> float:
        return self.energy().fps_per_watt

    def block_until_ready(self) -> "ExecutionResult":
        """Wait for the device computation (for timing/benchmarks)."""
        self.logits.block_until_ready()
        return self


def _norm_lowering(lowering):
    """Default + normalize: None -> the small CNN; OpGraph passes
    through; anything else is frozen into a legacy flat tuple (both
    forms are hashable, as static jit arguments must be)."""
    if lowering is None:
        return cnn_mod.small_cnn_lowering()
    if isinstance(lowering, lw.OpGraph):
        return lowering
    return tuple(lowering)


def _layer_matmul(cols: jnp.ndarray, w: jnp.ndarray, cfg: PhotonicConfig,
                  key: Optional[jax.Array], plan: LayerPlan,
                  impl: str) -> jnp.ndarray:
    return ops.photonic_matmul(cols, w, cfg, key=key, impl=impl,
                               block_m=plan.tile.block_m,
                               block_d=plan.tile.block_d)


# ---------------------------------------------------------------------------
# Pure forward (the jit-compiled hot path)
# ---------------------------------------------------------------------------
# Counts Python executions of the forward body.  Under jit the body runs
# only while TRACING, so a warm compiled call leaves the counter untouched
# — tests and benchmarks/throughput.py assert no-retrace with this.
# Guarded by a lock: concurrent serving threads may trace simultaneously
# (cold buckets), and ``count += 1`` is not atomic across the read/write —
# a lost increment would let a real retrace slip past the no-retrace gates.
_TRACE_COUNT = 0
_TRACE_LOCK = threading.Lock()


def trace_count() -> int:
    """How many times the forward body has been traced/executed in Python."""
    with _TRACE_LOCK:
        return _TRACE_COUNT


def _forward(params: Dict[str, jnp.ndarray], x: jnp.ndarray,
             key: Optional[jax.Array] = None, *,
             lowering, plan: CnnPlan, cfg: PhotonicConfig, impl: str,
             collect_activations: bool):
    """Pure forward: (params, x, key) -> (logits, fingerprints, acts).

    Walks the lowering's op graph (models.lowering.graph_forward): every
    GEMM-bearing node (conv / depthwise_conv / fc) runs through the
    photonic kernel with its LayerPlan's tiling and an independent noise
    key; glue nodes (pool / residual_add / concat / shuffle / slice) are
    plain jnp ops.  Everything after the array arguments is static
    configuration; no host sync happens anywhere in the body
    (fingerprints stay device arrays).  Fingerprints are per GEMM node,
    taken right after its activation (before any downstream glue).
    """
    global _TRACE_COUNT
    with _TRACE_LOCK:
        _TRACE_COUNT += 1
    graph = cnn_mod.as_graph(lowering, plan=plan)

    def mm(a2d: jnp.ndarray, w2d: jnp.ndarray, gi: int,
           node: lw.OpNode) -> jnp.ndarray:
        layer_key = (jax.random.fold_in(key, gi)
                     if key is not None and cfg.noise_enabled else None)
        return _layer_matmul(a2d, w2d, cfg, layer_key, plan.layers[gi],
                             impl)

    vals = lw.graph_forward(params, x, graph, mm)
    gemm_outs = [vals[n.name] for n in graph.gemm_nodes]
    # mean |activation| via explicit reciprocal multiply — jnp.mean's
    # division by the (constant) element count is reassociated by XLA
    # under jit but not eagerly, and the compiled-vs-eager contract
    # covers the fingerprints too.
    fingerprints = [jnp.sum(jnp.abs(v)) * (1.0 / v.size)
                    for v in gemm_outs]
    acts = tuple(gemm_outs) if collect_activations else ()
    return (vals[graph.output.name], jnp.stack(fingerprints), acts)


forward_fn = jax.jit(_forward, static_argnames=(
    "lowering", "plan", "cfg", "impl", "collect_activations"))
"""jit entry point: ``forward_fn(params, x, key, lowering=..., plan=...,
cfg=..., impl=..., collect_activations=...)`` with the keyword arguments
static — CnnPlan/LayerPlan/TileChoice and PhotonicConfig are hashable by
value precisely so they can sit in jit's cache key."""


def lowering_fingerprint(lowering) -> str:
    """Content address of a lowered network structure (not its weights).

    Covers both forms: op graphs hash every node field; legacy flat
    tuples keep their historical layout (under a bumped version — the
    graph path changed what a lowering can express)."""
    if isinstance(lowering, lw.OpGraph):
        layers = [dataclasses.asdict(n) for n in lowering.nodes]
        for d in layers:
            d["inputs"] = list(d["inputs"])
    else:
        layers = [[l.name, l.kind, l.relu, l.pool_after, l.kk]
                  for l in lowering]
    return pc.fingerprint({"v": _LOWERING_FP_VERSION, "layers": layers})


# Executable-wrapper memo: (lowering fp, per-layer plan cache keys, cfg,
# impl, collect) -> partial over forward_fn.  jax.jit's own cache then
# adds the batch shape/dtype — together that is the compilation cache
# serving calls hit.  LRU-bounded for the same reason PlanCache is: a
# long-lived serving process streaming distinct plans must not grow
# without limit.  (Evicting a wrapper drops its pinned CnnPlan/lowering;
# traced executables already in jit's global cache are NOT reclaimed —
# call jax.clear_caches() if that ever matters.)
#
# All access goes through _FORWARD_LOCK: the serving front-end
# (exec.serving) calls compiled_forward from concurrent request threads,
# and an unguarded get/insert/move_to_end/popitem sequence on the
# OrderedDict can corrupt its internal linkage or evict mid-iteration.
_FORWARD_CACHE: "OrderedDict[tuple, Callable]" = OrderedDict()
_FORWARD_CACHE_MAX = 256
_FORWARD_LOCK = threading.RLock()


def compiled_forward(plan: CnnPlan, cfg: PhotonicConfig,
                     lowering: Optional[Lowering] = None,
                     impl: str = "auto",
                     collect_activations: bool = False) -> Callable:
    """The compiled serving entry: returns ``fn(params, x, key=None)``.

    Warm calls execute a cached jit executable — no retracing, no
    per-layer host syncs.  Two plans that solve the same planning problems
    (same content-addressed cache keys) share one wrapper even if they are
    distinct objects.  Thread-safe: concurrent serving threads may call
    this freely (they serialize only on the memo lookup, not the forward).
    """
    lowering = _norm_lowering(lowering)
    impl = "pallas" if impl == "auto" else impl
    memo_key = (lowering_fingerprint(lowering),
                tuple(p.cache_key for p in plan.layers), cfg, impl,
                collect_activations)
    with _FORWARD_LOCK:
        fn = _FORWARD_CACHE.get(memo_key)
        if fn is None:
            fn = functools.partial(forward_fn, lowering=lowering, plan=plan,
                                   cfg=cfg, impl=impl,
                                   collect_activations=collect_activations)
            _FORWARD_CACHE[memo_key] = fn
            while len(_FORWARD_CACHE) > _FORWARD_CACHE_MAX:
                _FORWARD_CACHE.popitem(last=False)
        else:
            _FORWARD_CACHE.move_to_end(memo_key)
        return fn


def compile_cache_stats() -> dict:
    with _FORWARD_LOCK:
        return {"entries": len(_FORWARD_CACHE),
                "max_entries": _FORWARD_CACHE_MAX}


def clear_compile_cache() -> None:
    with _FORWARD_LOCK:
        _FORWARD_CACHE.clear()
        _validate_geometry.cache_clear()


# ---------------------------------------------------------------------------
# Validation (eager, before tracing — clear errors instead of reshape noise)
# ---------------------------------------------------------------------------
def _gemm_count(lowering) -> int:
    if isinstance(lowering, lw.OpGraph):
        return len(lowering.gemm_nodes)
    return len(lowering)


def _validate(x: jnp.ndarray, plan: CnnPlan, cfg: PhotonicConfig,
              lowering, key: Optional[jax.Array]) -> None:
    if x.ndim != 4:
        raise ValueError(f"x must be (N, H, W, C) images, got shape "
                         f"{tuple(x.shape)}")
    if len(plan.layers) != _gemm_count(lowering):
        raise ValueError(
            f"plan has {len(plan.layers)} layers, lowering has "
            f"{_gemm_count(lowering)} GEMM layers — plan the "
            f"lowered_gemms of this network")
    n, h, w = x.shape[0], x.shape[1], x.shape[2]
    if n != plan.batch:
        raise ValueError(
            f"plan was scheduled for batch {plan.batch} but x has batch "
            f"{n} — modeled and executed numbers would disagree; for "
            f"mixed-size traffic use exec.serving.ServingEngine, which "
            f"pads each request up to a power-of-two batch bucket with "
            f"its own pre-traced plan and slices the results back")
    if cfg.noise_enabled and key is None:
        raise ValueError(
            "cfg.noise_enabled=True but key=None — pass a root PRNG key "
            "(per-layer keys are folded in) or set noise_enabled=False")
    # Kernel-cfg / plan hardware coherence: a PhotonicConfig whose DPE
    # geometry, backend or data rate disagrees with the hardware the plan
    # was scheduled for used to execute without complaint — the numerics
    # then silently diverged from the modeled latency/energy the result
    # reports.  Plans carrying an OperatingPoint (plan v4) additionally
    # pin bits and optics.
    hw.check_kernel_plan_coherence(cfg, plan)
    # lru_cache's C implementation is safe on CPython, but the contract
    # here ("warm loop pays the graph walk once") shouldn't depend on
    # that detail: serialize on the same lock the wrapper memo uses so
    # concurrent serving threads can't interleave memo fill + clear.
    with _FORWARD_LOCK:
        _validate_geometry(lowering, plan, h, w)


@functools.lru_cache(maxsize=_FORWARD_CACHE_MAX)
def _validate_geometry(lowering, plan: CnnPlan, h: int, w: int) -> None:
    """Structural checks, memoized: the outcome is fully determined by
    (lowering, plan, H, W) — all hashable — so a warm serving loop pays
    the Python graph walk once per distinct geometry, not per call.
    (lru_cache does not cache raises: invalid combinations re-raise
    their clear error every call.)  Bounded like _FORWARD_CACHE — each
    entry pins its plan/lowering — and cleared by clear_compile_cache.

    Infers every node's shape for THESE spatial dims — raising the IR's
    explicit errors for indivisible pooling / mismatched branches —
    then pins each GEMM node against its LayerPlan: the plan must have
    been built for exactly this input geometry.
    """
    graph = cnn_mod.as_graph(lowering, plan=plan)
    shapes = lw.infer_shapes(graph, (h, w))
    for node, lplan in zip(graph.gemm_nodes, plan.layers):
        oh, ow, oc = shapes[node.name]
        rows = plan.batch if node.op == "fc" else plan.batch * oh * ow
        if lplan.c != rows:
            where = (f"the batch is {plan.batch}" if node.op == "fc" else
                     f"the input reaches this layer as {plan.batch} x "
                     f"{oh}x{ow} = {rows} rows")
            raise ValueError(
                f"{node.name}: plan expects {lplan.c} GEMM rows but "
                f"{where} — plan_for_network(in_hw=({h}, {w})) "
                f"for this input size")
        if node.op == "depthwise_conv":
            ic = shapes[node.inputs[0]][2]
            if lplan.count != ic:
                raise ValueError(
                    f"{node.name}: plan has count={lplan.count} depthwise "
                    f"groups but the input reaches this layer with "
                    f"{ic} channels — replan this network")
        elif lplan.d != oc:
            raise ValueError(
                f"{node.name}: plan has D={lplan.d} output channels but "
                f"the lowering implies {oc} — plan and lowering come "
                f"from different networks")


# ---------------------------------------------------------------------------
# Public wrapper (today's ExecutionResult API)
# ---------------------------------------------------------------------------
def execute_cnn(params: Dict[str, jnp.ndarray], x: jnp.ndarray,
                plan: CnnPlan, cfg: PhotonicConfig,
                key: Optional[jax.Array] = None,
                impl: str = "auto",
                lowering: Optional[Lowering] = None,
                collect_activations: bool = False,
                compiled: bool = True) -> ExecutionResult:
    """Run a lowered CNN end-to-end through the photonic kernel.

    params: weight dict keyed by GEMM-node (or LoweredLayer) name.
    x: (N, H, W, C) image batch (H != W is fine; the plan must have been
      built for the same spatial dims, see plan_for_network(in_hw=...)).
    plan: CnnPlan over lowered_gemms(params, lowering) at batch >= 1 —
      layer order must match the lowering's GEMM nodes (schedule_cnn
      preserves it).
    key: root PRNG key for detection noise (per-layer keys are folded in);
      REQUIRED when cfg.noise_enabled, forbidden-to-matter otherwise.
    impl: 'pallas' | 'ref' | 'auto' (forwarded to ops.photonic_matmul).
    lowering: an op-graph (models.lowering.OpGraph — models.zoo_cnn holds
      the paper networks' runnable variants) or a legacy flat
      LoweredLayer tuple; defaults to the small CNN.
    compiled: route through the jit-compiled forward (default).  False
      runs the same body op-by-op in Python — the slow pre-fix behavior,
      kept as the measurable baseline for benchmarks/throughput.py.
    """
    lowering = _norm_lowering(lowering)
    impl = "pallas" if impl == "auto" else impl
    _validate(x, plan, cfg, lowering, key)
    if compiled:
        fn = compiled_forward(plan, cfg, lowering, impl,
                              collect_activations)
        logits, fingerprints, acts = fn(params, x, key)
    else:
        logits, fingerprints, acts = _forward(
            params, x, key, lowering=lowering, plan=plan, cfg=cfg,
            impl=impl, collect_activations=collect_activations)
    return ExecutionResult(
        logits=logits, plan=plan, fingerprints=fingerprints,
        activations=list(acts) if collect_activations else None)


def reference_forward(params: Dict[str, jnp.ndarray], x: jnp.ndarray,
                      cfg: PhotonicConfig,
                      lowering: Optional[Lowering] = None) -> jnp.ndarray:
    """Pure-jnp oracle forward: same quantize->accumulate->ADC math via
    kernels/ref.py, driven through the SAME lowered structure the executor
    runs (models.cnn.lowered_apply) — so the oracle covers any lowered
    network, not just the small CNN.

    The bit-exactness contract (noise disabled): execute_cnn(...,
    impl='pallas') must equal this exactly — the Pallas path introduces
    zero numeric deviation, padding included.  A noise-enabled cfg raises
    (the oracle is deterministic by definition; disable noise explicitly).
    """
    mm: Callable = lambda a, w: ops.photonic_matmul(a, w, cfg, impl="ref")
    return cnn_mod.lowered_apply(params, x, _norm_lowering(lowering),
                                 matmul=mm)


def plan_for_network(params: Dict[str, jnp.ndarray],
                     acc, batch: int = 1, in_hw=16,
                     lowering: Optional[Lowering] = None,
                     **schedule_kw) -> CnnPlan:
    """Convenience: lower a runnable network's GEMM table and schedule it.

    ``acc``: an AcceleratorConfig or (preferred) a core.hw.OperatingPoint
    — the latter is embedded in the plan so the executor can hold the
    kernel config coherent with it.
    ``in_hw``: input spatial size — an int for square images or an (H, W)
    pair for rectangular ones.
    """
    from repro.exec.scheduler import schedule_cnn
    gemms = cnn_mod.lowered_gemms(params, lowering, in_hw)
    return schedule_cnn(gemms, acc, batch=batch, **schedule_kw)

"""End-to-end CNN executor over the Pallas TAOM kernel.

Runs a *runnable* GEMM-lowered CNN (models.cnn.LoweredLayer structure +
params dict) image-batch in, logits out, with every GEMM executed by
kernels.ops.photonic_matmul: quantize -> TAOM kernel (Pallas; interpreted
on CPU) -> rescale.  This turns the repo's analytic per-figure scripts
into an actual inference engine producing real activations.

Batching follows the paper's Toeplitz accounting: the image batch folds
into the GEMM M axis (all images' im2col rows concatenated), which is both
the batch-serving shape and what core.perf_model charges for batched
layers.  Detection-noise keys are threaded per layer — fold_in(key,
layer_index) — so every layer draws independent noise and runs are
reproducible from one root key.

The executor consumes a CnnPlan from exec.scheduler: each layer's GEMM
uses the plan's kernel tiling (block_m, block_d).  The plan's *dataflow*
choice changes scheduling (latency/energy in the report), never numerics —
with noise disabled the executed network equals the pure-jnp reference
(kernels/ref.py) bit-exactly, whatever the plan says (tests pin this).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.types import PhotonicConfig
from repro.exec.scheduler import CnnPlan, LayerPlan
from repro.kernels import ops
from repro.models import cnn as cnn_mod


@dataclasses.dataclass
class LayerTrace:
    """What actually ran for one layer (executed next to modeled)."""
    name: str
    m: int
    k: int
    d: int
    dataflow: str
    block_m: int
    block_d: int
    latency_s: float       # modeled (from the plan)
    energy_j: float        # modeled (from the plan)
    out_mean_abs: float    # executed-numerics fingerprint


@dataclasses.dataclass
class ExecutionResult:
    logits: jnp.ndarray
    plan: CnnPlan
    traces: List[LayerTrace]
    activations: Optional[List[jnp.ndarray]] = None

    @property
    def modeled_latency_s(self) -> float:
        return self.plan.latency_s

    @property
    def modeled_fps(self) -> float:
        return self.plan.fps


def _maxpool2x2(x: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                 (1, 2, 2, 1), "VALID")


def _layer_matmul(cols: jnp.ndarray, w: jnp.ndarray, cfg: PhotonicConfig,
                  key: Optional[jax.Array], plan: LayerPlan,
                  impl: str) -> jnp.ndarray:
    return ops.photonic_matmul(cols, w, cfg, key=key, impl=impl,
                               block_m=plan.tile.block_m,
                               block_d=plan.tile.block_d)


def execute_cnn(params: Dict[str, jnp.ndarray], x: jnp.ndarray,
                plan: CnnPlan, cfg: PhotonicConfig,
                key: Optional[jax.Array] = None,
                impl: str = "auto",
                lowering: Optional[Sequence[cnn_mod.LoweredLayer]] = None,
                collect_activations: bool = False) -> ExecutionResult:
    """Run a lowered CNN end-to-end through the photonic kernel.

    params: weight dict keyed by LoweredLayer.name, each (K, D).
    x: (N, H, W, C) image batch.
    plan: CnnPlan over lowered_gemms(params, lowering) at batch >= 1 —
      layer order must match the lowering (schedule_cnn preserves it).
    key: root PRNG key for detection noise (per-layer keys are folded in);
      None or cfg.noise_enabled=False runs deterministically.
    impl: 'pallas' | 'ref' | 'auto' (forwarded to ops.photonic_matmul).
    """
    lowering = tuple(lowering or cnn_mod.small_cnn_lowering())
    if len(plan.layers) != len(lowering):
        raise ValueError(
            f"plan has {len(plan.layers)} layers, lowering has "
            f"{len(lowering)} — plan the lowered_gemms of this network")
    n = x.shape[0]
    if n != plan.batch:
        raise ValueError(
            f"plan was scheduled for batch {plan.batch} but x has batch "
            f"{n} — modeled and executed numbers would disagree")
    traces: List[LayerTrace] = []
    acts: List[jnp.ndarray] = []

    for idx, (lyr, lplan) in enumerate(zip(lowering, plan.layers)):
        w = params[lyr.name]
        layer_key = (jax.random.fold_in(key, idx)
                     if key is not None and cfg.noise_enabled else None)
        if lyr.kind == "conv":
            hw = x.shape[1]
            cols = cnn_mod._im2col(x, lyr.kk)           # (N, HW, K)
            out = _layer_matmul(cols.reshape(-1, cols.shape[-1]), w, cfg,
                                layer_key, lplan, impl)
            x = out.reshape(n, hw, hw, w.shape[-1])
        elif lyr.kind == "fc":
            out = _layer_matmul(x.reshape(n, -1), w, cfg, layer_key, lplan,
                                impl)
            x = out
        else:
            raise ValueError(f"unknown lowered-layer kind: {lyr.kind!r}")
        if lyr.relu:
            x = jax.nn.relu(x)
        if lyr.pool_after:
            x = _maxpool2x2(x)
        traces.append(LayerTrace(
            name=lyr.name, m=out.shape[0] if out.ndim == 2 else -1,
            k=w.shape[0], d=w.shape[1], dataflow=lplan.dataflow.value,
            block_m=lplan.tile.block_m, block_d=lplan.tile.block_d,
            latency_s=lplan.latency_s, energy_j=lplan.energy_j,
            out_mean_abs=float(jnp.mean(jnp.abs(x)))))
        if collect_activations:
            acts.append(x)

    return ExecutionResult(logits=x, plan=plan, traces=traces,
                           activations=acts if collect_activations else None)


def reference_forward(params: Dict[str, jnp.ndarray], x: jnp.ndarray,
                      cfg: PhotonicConfig) -> jnp.ndarray:
    """Pure-jnp oracle forward: same quantize->accumulate->ADC math via
    kernels/ref.py, driven through the model's own apply function.

    The bit-exactness contract (noise disabled): execute_cnn(...,
    impl='pallas') must equal this exactly — the Pallas path introduces
    zero numeric deviation, padding included.
    """
    mm: Callable = lambda a, w: ops.photonic_matmul(a, w, cfg, impl="ref")
    return cnn_mod.small_cnn_apply(params, x, matmul=mm)


def plan_for_network(params: Dict[str, jnp.ndarray],
                     acc, batch: int = 1, in_hw: int = 16,
                     lowering: Optional[Sequence[cnn_mod.LoweredLayer]] = None,
                     **schedule_kw) -> CnnPlan:
    """Convenience: lower a runnable network's GEMM table and schedule it."""
    from repro.exec.scheduler import schedule_cnn
    gemms = cnn_mod.lowered_gemms(params, lowering, in_hw)
    return schedule_cnn(gemms, acc, batch=batch, **schedule_kw)

"""Batched multi-device serving engine over the compiled executor.

The executor's compiled hot path (exec.executor.compiled_forward) serves
one batch shape per plan: requests whose batch differs from ``plan.batch``
are rejected, and every new shape pays a trace.  Real CNN traffic arrives
in mixed sizes (see PAPERS.md, arXiv:2207.05278 — the system, not the
user, must map mixed-size tensors onto fixed hardware shapes), so this
module adds the serving layer HEANA's buffer-less "never stall" pitch
implies:

  * **batch buckets** — power-of-two batch sizes, each with its own
    ahead-of-time CnnPlan (scheduler.schedule_buckets on one shared plan
    cache).  An incoming request is zero-padded up to the smallest bucket
    that fits and the results are sliced back; requests larger than the
    top bucket are chunked.  Zero padding is numerics-neutral: the
    per-tensor quantize scale is a max over |activations| and the padded
    images stay zero through every layer, so the real rows' logits are
    bitwise what an exact-size batch would produce.  (Chunking is not:
    each chunk is its own batch, and the dynamic per-batch quantize scale
    means an over-max_batch request equals the concatenation of exact-size
    chunk runs — not one giant batch run.  The same holds for the
    micro-batcher: coalescing requests into one batch quantizes them
    together, so a coalesced request can differ from a solo run in the
    last quantization ULP — by design, exactly like batching on the real
    hardware's shared ADC range.);

  * **warmup()** — pre-traces every (bucket, sharding) executable with a
    dummy batch, so no serving request ever pays a trace (zero retraces
    after warmup is asserted by benchmarks/serving.py and CI);

  * a thread-safe **micro-batcher** — coalesces single-image requests
    from a queue into bucketed batches under a max-delay knob, resolving
    each request's Future with its row of the batched logits;

  * a **multi-device data-parallel path** — the bucketed batch is placed
    on a NamedSharding over the image batch axis of a 1-D ('data',) mesh
    (the spirit of parallel/sharding.py's batch_sharding) and the
    already-jitted forward is GSPMD-partitioned by XLA.  Because the
    contraction (K) axis is never sharded and the global quantize-scale
    max becomes an exact all-reduce max, the data-parallel logits are
    BITWISE equal to single-device execution when noise is off
    (benchmarks/serving.py checks this on 4 virtual CPU devices);

  * **serving metrics** — p50/p99 request latency, sustained throughput,
    padding-overhead fraction, the plan/compile cache stats surfaced
    from the existing ``stats()`` hooks, and the photonic model's energy
    accounting of the served stream (modeled joules per inference —
    padding included, that's the cost of bucketing — and sustained
    watts), derived from each bucket plan via core.hw.trace_energy.

Noise: a noise-enabled engine requires a root PRNG key per ``infer`` call
(per-chunk keys are folded in, per-layer keys inside the forward).  The
data-parallel path is noise-off only — per-shard noise streams would
diverge from the single-device stream, silently breaking reproducibility.
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import hw
from repro.core.types import PhotonicConfig
from repro.exec import executor as ex
from repro.exec import plan_cache as pc
from repro.exec.scheduler import CnnPlan, HardwareSpec, schedule_buckets
from repro.models import cnn as cnn_mod

__all__ = ["ServingEngine", "MicroBatcher", "power_of_two_buckets",
           "bucket_for"]

#: How many recent request latencies the metrics window keeps.
_LATENCY_WINDOW = 16384


def power_of_two_buckets(max_batch: int) -> Tuple[int, ...]:
    """(1, 2, 4, ..., max_batch) with max_batch rounded UP to a power
    of two — a request never lands in a smaller bucket than itself."""
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    buckets: List[int] = [1]
    while buckets[-1] < max_batch:
        buckets.append(buckets[-1] * 2)
    return tuple(buckets)


def bucket_for(n: int, buckets: Sequence[int]) -> int:
    """Smallest bucket >= n (buckets ascending; n must fit the largest)."""
    for b in buckets:
        if b >= n:
            return b
    raise ValueError(f"batch {n} exceeds the largest bucket "
                     f"{buckets[-1]} — the engine chunks before bucketing, "
                     f"so this is an internal error")


def _percentile(sorted_vals: Sequence[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, int(q * len(sorted_vals)))
    return sorted_vals[idx]


class ServingEngine:
    """Bucketed, warmed-up, optionally data-parallel CNN serving.

    One engine serves one network (lowering + params) on one accelerator
    config.  All entry points are thread-safe: concurrent request threads
    share the pre-traced executables and serialize only on metrics
    bookkeeping (the forward itself runs outside any lock).

    Parameters
    ----------
    params, acc, cfg : the executor's usual weight dict, the hardware
        (an AcceleratorConfig, or — preferred — a core.hw.OperatingPoint,
        in which case ``cfg`` may be omitted and is derived coherently
        via ``op.kernel_config()``), and the PhotonicConfig numerics.
        A ``cfg`` whose bits/DPE geometry disagrees with the plans'
        hardware is rejected HERE, at construction — not after the first
        mis-modeled request.
    lowering : op-graph / legacy tuple; default small CNN.
    in_hw : input spatial size (int or (H, W)).
    max_batch : largest bucket (rounded up to a power of two).  Larger
        requests are chunked into top-bucket pieces.
    data_parallel : shard bucketed batches over ``devices`` (default
        ``jax.devices()``) via NamedSharding on the batch axis.  Buckets
        not divisible by the device count fall back to single-device.
        Requires cfg.noise_enabled=False.
    plan_cache : shared PlanCache (fresh one per engine by default).
    """

    def __init__(self, params: dict, acc: HardwareSpec,
                 cfg: Optional[PhotonicConfig] = None, lowering=None,
                 in_hw=16, max_batch: int = 32, impl: str = "auto",
                 objective: str = "latency",
                 plan_cache: Optional[pc.PlanCache] = None,
                 data_parallel: bool = False,
                 devices: Optional[Sequence] = None) -> None:
        if cfg is None:
            if not isinstance(acc, hw.OperatingPoint):
                raise ValueError(
                    "cfg is required when acc is a bare AcceleratorConfig "
                    "— pass a PhotonicConfig, or hand the engine a "
                    "core.hw.OperatingPoint and let it derive the kernel "
                    "config coherently (op.kernel_config())")
            cfg = acc.kernel_config()
        self._params = params
        self._cfg = cfg
        self._impl = impl
        self._lowering = ex._norm_lowering(lowering)
        self._in_hw = ((in_hw, in_hw) if isinstance(in_hw, int)
                       else (int(in_hw[0]), int(in_hw[1])))
        self._in_ch = cnn_mod.as_graph(self._lowering,
                                       params=params).input.cout
        self.buckets = power_of_two_buckets(max_batch)
        self.plan_cache = (plan_cache if plan_cache is not None
                           else pc.PlanCache())
        gemms = cnn_mod.lowered_gemms(params, self._lowering, self._in_hw)
        self.plans: Dict[int, CnnPlan] = schedule_buckets(
            gemms, acc, self.buckets, objective, cache=self.plan_cache)
        # Fail fast on incoherent hardware: every bucket shares one
        # hardware spec, so checking any plan pins cfg against all of
        # them.  (The executor re-checks per request via _validate — this
        # just moves the clear error to construction time.)
        hw.check_kernel_plan_coherence(cfg, self.plans[self.buckets[0]])
        # One compiled wrapper per bucket, built up front: the jit
        # executables themselves materialize at warmup()/first call.
        self._fns = {b: ex.compiled_forward(self.plans[b], cfg,
                                            self._lowering, impl)
                     for b in self.buckets}

        self.devices = (list(devices) if devices is not None
                        else list(jax.devices()))
        self.data_parallel = bool(data_parallel) and len(self.devices) > 1
        if bool(data_parallel) and cfg.noise_enabled:
            raise ValueError(
                "data_parallel serving requires noise_enabled=False — "
                "per-shard noise streams would not reproduce the "
                "single-device stream (run noisy inference single-device)")
        if self.data_parallel:
            self._mesh = Mesh(np.asarray(self.devices), ("data",))
            self._x_sharding = NamedSharding(self._mesh,
                                             P("data", None, None, None))
            self._params_dp = jax.device_put(
                params, NamedSharding(self._mesh, P()))

        self._lock = threading.Lock()
        self._latencies: List[float] = []
        self._requests = 0
        self._images = 0
        self._blocked_images = 0
        self._batches = 0
        self._padded_slots = 0
        self._executed_slots = 0
        self._busy_s = 0.0
        self._warm = False
        self._retraces = 0
        # Modeled photonic energy of the executed stream: per-bucket
        # joules/latency are precomputed once from the plans (core.hw
        # executed-trace accounting) and accumulated per executed batch —
        # padding slots burn real energy, so a padded bucket is charged
        # in full (the padding overhead is visible in j_per_image).
        self._bucket_energy = {b: hw.trace_energy(self.plans[b])
                               for b in self.buckets}
        self._energy_j = 0.0
        self._model_time_s = 0.0

    # -- bucket plumbing -----------------------------------------------------
    @property
    def max_bucket(self) -> int:
        return self.buckets[-1]

    def _dp_bucket(self, bucket: int) -> bool:
        return self.data_parallel and bucket % len(self.devices) == 0

    def _run_bucket(self, xb: jnp.ndarray, key, bucket: int) -> jnp.ndarray:
        fn = self._fns[bucket]
        traces0 = ex.trace_count() if self._warm else 0
        if self._dp_bucket(bucket):
            xb = jax.device_put(xb, self._x_sharding)
            logits, _, _ = fn(self._params_dp, xb, key)
        else:
            logits, _, _ = fn(self._params, xb, key)
        if self._warm:
            # Engine-local retrace accounting: tally only traces that
            # happened across THIS engine's calls — another engine's
            # warmup elsewhere in the process must not show up here.
            traced = ex.trace_count() - traces0
            if traced:
                with self._lock:
                    self._retraces += traced
        return logits

    def _infer_chunk(self, chunk: jnp.ndarray, key) -> jnp.ndarray:
        n = chunk.shape[0]
        bucket = bucket_for(n, self.buckets)
        pad = bucket - n
        xb = (chunk if pad == 0 else jnp.concatenate(
            [chunk, jnp.zeros((pad,) + chunk.shape[1:], chunk.dtype)]))
        # The executor's own eager validation surfaces its clear errors
        # (geometry mismatch, noise-without-key) through the serving
        # entry point, before anything touches the compiled path.
        ex._validate(xb, self.plans[bucket], self._cfg, self._lowering, key)
        logits = self._run_bucket(xb, key, bucket)
        te = self._bucket_energy[bucket]
        with self._lock:
            self._batches += 1
            self._padded_slots += pad
            self._executed_slots += bucket
            self._energy_j += te.energy_j
            self._model_time_s += te.latency_s
        return logits[:n] if pad else logits

    # -- public entry points -------------------------------------------------
    def warmup(self, key: Optional[jax.Array] = None) -> Dict[int, float]:
        """Pre-trace every (bucket, sharding) executable with a dummy
        batch so no serving request ever pays a trace.  Returns
        {bucket: cold_seconds}.  With noise enabled a dummy root key is
        used — serving keys reuse the same executable (same key shape).
        """
        if key is None and self._cfg.noise_enabled:
            key = jax.random.PRNGKey(0)
        if not self._cfg.noise_enabled:
            key = None
        h, w = self._in_hw
        cold: Dict[int, float] = {}
        for b in self.buckets:
            x = jnp.zeros((b, h, w, self._in_ch), jnp.float32)
            t0 = time.perf_counter()
            self._run_bucket(x, key, b).block_until_ready()
            cold[b] = time.perf_counter() - t0
        with self._lock:
            self._warm = True
            self._retraces = 0
        return cold

    def infer(self, x, key: Optional[jax.Array] = None,
              block: bool = True) -> jnp.ndarray:
        """Serve one request: (N, H, W, C) images -> (N, classes) logits.

        N is arbitrary: it is padded up to the smallest bucket that fits
        (chunked into top-bucket pieces first if N > max_bucket; with a
        key, each chunk folds in its index so chunk noise stays
        independent).  ``block=True`` (default) waits for the device so
        the recorded latency is true request latency; ``block=False``
        returns the dispatched arrays immediately — such calls still
        count toward request/image/padding totals but are EXCLUDED from
        the latency percentiles and sustained_ips (a dispatch-only
        duration is not a request latency).
        """
        t0 = time.perf_counter()
        x = jnp.asarray(x)
        if x.ndim != 4:
            raise ValueError(f"x must be (N, H, W, C) images, got shape "
                             f"{tuple(x.shape)} — for a single image use "
                             f"infer_one or x[None]")
        n = x.shape[0]
        if n == 0:
            raise ValueError("empty request: x has batch 0")
        if not self._cfg.noise_enabled:
            key = None          # keep one executable per bucket
        outs: List[jnp.ndarray] = []
        start, ci = 0, 0
        n_chunks = -(-n // self.max_bucket)
        while start < n:
            take = min(self.max_bucket, n - start)
            ck = (jax.random.fold_in(key, ci)
                  if key is not None and n_chunks > 1 else key)
            outs.append(self._infer_chunk(x[start:start + take], ck))
            start += take
            ci += 1
        logits = outs[0] if len(outs) == 1 else jnp.concatenate(outs)
        if block:
            logits.block_until_ready()
        dt = time.perf_counter() - t0
        with self._lock:
            self._requests += 1
            self._images += n
            if block:
                self._blocked_images += n
                self._busy_s += dt
                self._latencies.append(dt)
                if len(self._latencies) > _LATENCY_WINDOW:
                    del self._latencies[:-_LATENCY_WINDOW]
        return logits

    def infer_one(self, image, key: Optional[jax.Array] = None
                  ) -> jnp.ndarray:
        """Serve a single (H, W, C) image -> (classes,) logits."""
        image = jnp.asarray(image)
        if image.ndim != 3:
            raise ValueError(f"image must be (H, W, C), got shape "
                             f"{tuple(image.shape)}")
        return self.infer(image[None], key=key)[0]

    def stats(self) -> dict:
        """Serving metrics + the underlying cache/trace hooks."""
        with self._lock:
            lat = sorted(self._latencies)
            warm = self._warm
            retraces = self._retraces
            out = {
                "requests": self._requests,
                "images": self._images,
                "batches": self._batches,
                "padded_slots": self._padded_slots,
                "executed_slots": self._executed_slots,
                "padding_fraction": (
                    self._padded_slots / self._executed_slots
                    if self._executed_slots else 0.0),
                "latency_p50_s": _percentile(lat, 0.50),
                "latency_p99_s": _percentile(lat, 0.99),
                "latency_mean_s": (sum(lat) / len(lat)) if lat else 0.0,
                "sustained_ips": (self._blocked_images / self._busy_s
                                  if self._busy_s > 0 else 0.0),
                "buckets": list(self.buckets),
                "data_parallel": self.data_parallel,
                "n_devices": len(self.devices),
                "warmed_up": warm,
                # Photonic-model energy of the served stream (NOT host
                # wall-clock electricity): joules per *real* inference —
                # padding overhead included, that's the serving cost of
                # bucketing — and the accelerator's sustained draw over
                # the modeled busy time.
                "modeled_energy_j": self._energy_j,
                "modeled_j_per_image": (self._energy_j / self._images
                                        if self._images else 0.0),
                "modeled_sustained_w": (self._energy_j / self._model_time_s
                                        if self._model_time_s > 0 else 0.0),
            }
        out["retraces_since_warmup"] = retraces if warm else None
        out["plan_cache"] = self.plan_cache.stats()
        out["compile_cache"] = ex.compile_cache_stats()
        return out


class MicroBatcher:
    """Thread-safe request coalescer: single images in, bucketed batches
    through a ServingEngine, per-request Futures out.

    A background worker takes the first queued request, then keeps
    gathering until either ``max_batch`` requests are in hand or
    ``max_delay_s`` has elapsed since the first one — the classic
    latency/throughput knob.  The stacked batch goes through
    ``engine.infer`` (which pads it to a bucket), and each Future
    resolves with its own row of the logits.

    With a noise-enabled engine pass a root ``key``: each formed batch
    folds in a monotonic counter, so batches draw independent noise and
    a given (key, arrival order) replays exactly.
    """

    def __init__(self, engine: ServingEngine, max_delay_s: float = 0.002,
                 max_batch: Optional[int] = None,
                 key: Optional[jax.Array] = None) -> None:
        if max_delay_s < 0:
            raise ValueError(f"max_delay_s must be >= 0, got {max_delay_s}")
        self._engine = engine
        self._max_delay_s = float(max_delay_s)
        self._max_batch = int(max_batch or engine.max_bucket)
        if self._max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if engine._cfg.noise_enabled and key is None:
            raise ValueError(
                "engine has noise_enabled=True: MicroBatcher needs a root "
                "PRNG key (per-batch keys are folded in)")
        self._key = key
        self._batch_counter = 0
        self._queue: "queue.Queue[tuple]" = queue.Queue()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()
        self._batches_formed = 0
        self._requests_batched = 0

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "MicroBatcher":
        if self._thread is not None:
            raise RuntimeError("MicroBatcher already started")
        self._thread = threading.Thread(target=self._run,
                                        name="micro-batcher", daemon=True)
        self._thread.start()
        return self

    def stop(self, timeout: Optional[float] = 5.0) -> None:
        """Stop the worker after draining already-queued requests.

        A submit() that passed its stopped-check concurrently with this
        call may enqueue after the worker exits; the drain below picks
        such stragglers up so no accepted Future is left unresolved.
        """
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=timeout)
            self._thread = None
        self._drain_now()

    def __enter__(self) -> "MicroBatcher":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- request path --------------------------------------------------------
    def submit(self, image) -> "Future":
        """Enqueue one (H, W, C) image; the Future resolves to its
        (classes,) logits (or raises what the engine raised)."""
        if self._stop.is_set():
            raise RuntimeError("MicroBatcher is stopped")
        image = jnp.asarray(image)
        if image.ndim != 3:
            raise ValueError(f"image must be (H, W, C), got shape "
                             f"{tuple(image.shape)}")
        fut: Future = Future()
        self._queue.put((image, fut))
        return fut

    def _next_key(self):
        if self._key is None:
            return None
        k = jax.random.fold_in(self._key, self._batch_counter)
        self._batch_counter += 1
        return k

    def _drain_now(self) -> None:
        """Dispatch everything currently queued, in bucket-size groups
        (queue.get is atomic, so a concurrent worker and a draining
        stop() cannot double-dispatch a request)."""
        while True:
            group: list = []
            while len(group) < self._max_batch:
                try:
                    group.append(self._queue.get_nowait())
                except queue.Empty:
                    break
            if not group:
                return
            self._dispatch(group)

    def _run(self) -> None:
        while True:
            try:
                first = self._queue.get(timeout=0.02)
            except queue.Empty:
                if self._stop.is_set():
                    self._drain_now()      # requests that raced the stop
                    return
                continue
            batch = [first]
            deadline = time.perf_counter() + self._max_delay_s
            while len(batch) < self._max_batch:
                remaining = deadline - time.perf_counter()
                if remaining <= 0:
                    break
                try:
                    batch.append(self._queue.get(timeout=remaining))
                except queue.Empty:
                    break
            self._dispatch(batch)

    def _dispatch(self, batch: list) -> None:
        try:
            # stack is inside the guard: mixed image shapes in one
            # coalescing window must fail THESE futures, not kill the
            # worker thread (which would hang every later request).
            images = jnp.stack([b[0] for b in batch])
            logits = self._engine.infer(images, key=self._next_key())
        except Exception as exc:  # surface engine errors per request
            for _, fut in batch:
                fut.set_exception(exc)
            return
        for i, (_, fut) in enumerate(batch):
            fut.set_result(logits[i])
        with self._lock:
            self._batches_formed += 1
            self._requests_batched += len(batch)

    def stats(self) -> dict:
        with self._lock:
            formed = self._batches_formed
            n = self._requests_batched
        return {"batches_formed": formed, "requests_batched": n,
                "mean_fill": (n / formed) if formed else 0.0,
                "max_delay_s": self._max_delay_s,
                "max_batch": self._max_batch,
                "queued": self._queue.qsize()}

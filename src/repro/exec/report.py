"""Report layer: plan + execution summaries for humans and for the
benchmark harness.

Aggregates the scheduler's per-layer modeled latency/energy next to the
executor's actual numerics, renders markdown (examples) and emits
JSON-safe dicts (benchmarks/autoflow.py caches them under
experiments/autoflow/ for benchmarks/report.py to assemble).
"""
from __future__ import annotations

import json
import os
from typing import Dict, Iterable, Optional

from repro.core.types import Dataflow
from repro.exec.executor import ExecutionResult
from repro.exec.scheduler import CnnPlan
from repro.models.lowering import OpGraph


def graph_summary(graph: OpGraph, name: str = "") -> dict:
    """JSON-safe structural summary of a lowered op graph (the zoo's
    networks): op histogram + GEMM-layer count, for reports/examples."""
    ops: Dict[str, int] = {}
    for n in graph.nodes:
        ops[n.op] = ops.get(n.op, 0) + 1
    return {
        "name": name,
        "n_nodes": len(graph.nodes),
        "n_gemm_layers": len(graph.gemm_nodes),
        "ops": ops,
        "output": graph.output.name,
    }


def plan_summary(plan: CnnPlan, name: str = "") -> dict:
    """JSON-safe summary of an auto-scheduled plan."""
    top = sorted(plan.layers, key=lambda p: -p.latency_s)[:5]
    return {
        "name": name,
        "backend": plan.acc.backend,
        "data_rate_gsps": plan.acc.data_rate_gsps,
        "batch": plan.batch,
        "objective": plan.objective,
        "n_layers": len(plan.layers),
        "dataflow_mix": plan.mix(),
        "fps": plan.fps,
        "fps_per_watt": plan.fps_per_watt,
        "latency_s": plan.latency_s,
        "energy_j": plan.result.energy_j,
        "cache_hits": plan.cache_hits,
        "cache_misses": plan.cache_misses,
        "top_layers": [
            {"name": p.name, "shape": [p.c, p.k, p.d],
             "dataflow": p.dataflow.value, "latency_s": p.latency_s,
             "share": p.latency_s / max(plan.latency_s, 1e-30)}
            for p in top],
    }


def plan_table(plan: CnnPlan, max_rows: int = 0) -> str:
    """Markdown per-layer table of an auto-scheduled plan."""
    rows = plan.layers[:max_rows] if max_rows else plan.layers
    total = max(plan.latency_s, 1e-30)
    lines = [
        "| layer | C | K | D | flow | tile (m,d) | latency | share |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for p in rows:
        lines.append(
            f"| {p.name} | {p.c} | {p.k} | {p.d} | {p.dataflow.value} | "
            f"{p.tile.block_m},{p.tile.block_d} | {p.latency_s:.3e} s | "
            f"{100 * p.latency_s / total:.1f}% |")
    if max_rows and len(plan.layers) > max_rows:
        lines.append(f"| ... {len(plan.layers) - max_rows} more | | | | | "
                     f"| | |")
    return "\n".join(lines)


def plan_vs_fixed(plan: CnnPlan, fixed: Dict[Dataflow, float]) -> dict:
    """Compare a plan's FPS against fixed-dataflow FPS numbers."""
    best_flow, best_fps = max(fixed.items(), key=lambda kv: kv[1])
    return {
        "auto_fps": plan.fps,
        "fixed_fps": {f.value: v for f, v in fixed.items()},
        "best_fixed_flow": best_flow.value,
        "best_fixed_fps": best_fps,
        "uplift": plan.fps / best_fps if best_fps > 0 else float("inf"),
    }


def execution_summary(res: ExecutionResult, name: str = "",
                      numerics: Optional[dict] = None) -> dict:
    """Modeled plan totals next to executed-numerics evidence."""
    energy = res.energy()
    out = {
        "name": name,
        "batch": res.plan.batch,
        "modeled_fps": res.plan.fps,
        "modeled_latency_s": res.plan.latency_s,
        "dataflow_mix": res.plan.mix(),
        "executed_energy_j": energy.energy_j,
        "executed_j_per_image": energy.j_per_image,
        "executed_fps_per_watt": energy.fps_per_watt,
        "energy_breakdown": {
            f: getattr(energy.breakdown, f)
            for f in ("laser", "dac", "adc", "tuning", "buffer",
                      "reduction", "static")},
        "layers": [
            {"name": t.name, "m": t.m, "k": t.k, "d": t.d,
             "dataflow": t.dataflow, "tile": [t.block_m, t.block_d],
             "latency_s": t.latency_s, "energy_j": t.energy_j,
             "executed_energy_j": t.executed_energy_j,
             "n_chunks": t.n_chunks,
             "adc_conversions": t.adc_conversions,
             "out_mean_abs": t.out_mean_abs}
            for t in res.traces],
    }
    if res.plan.op is not None:
        out["operating_point"] = res.plan.op.describe()
    if numerics:
        out["numerics"] = dict(numerics)
    return out


def throughput_summary(name: str, batch: int, compiled_ips: float,
                       eager_ips: float, modeled_fps: float,
                       extras: Optional[dict] = None) -> dict:
    """JSON-safe record of one compiled-vs-eager throughput measurement.

    ``*_ips`` are measured warm-call images/sec on the host simulation;
    ``modeled_fps`` is the photonic perf-model number for context (the
    two are different machines — never compare them directly).
    """
    out = {
        "kind": "throughput",
        "name": name,
        "batch": batch,
        "compiled_ips": compiled_ips,
        "eager_ips": eager_ips,
        "speedup": (compiled_ips / eager_ips) if eager_ips > 0
        else float("inf"),
        "modeled_fps": modeled_fps,
    }
    if extras:
        out.update(extras)
    return out


def serving_summary(name: str, batch_bucket: int, engine_stats: dict,
                    bucketed_ips: float, per_request_ips: float,
                    extras: Optional[dict] = None) -> dict:
    """JSON-safe record of one serving-engine measurement.

    ``bucketed_ips`` is the engine's sustained warm throughput for this
    cell; ``per_request_ips`` is the single-image-at-a-time baseline
    (batch-1 plan, one compiled call per image) the bucketed path is
    amortizing away.  ``engine_stats`` is ServingEngine.stats() — the
    padding/latency/cache evidence rides along verbatim.
    """
    out = {
        "kind": "serving",
        "name": name,
        "bucket": batch_bucket,
        "bucketed_ips": bucketed_ips,
        "per_request_ips": per_request_ips,
        "speedup": (bucketed_ips / per_request_ips) if per_request_ips > 0
        else float("inf"),
        "latency_p50_s": engine_stats.get("latency_p50_s"),
        "latency_p99_s": engine_stats.get("latency_p99_s"),
        "padding_fraction": engine_stats.get("padding_fraction"),
        "retraces_since_warmup": engine_stats.get("retraces_since_warmup"),
        "data_parallel": engine_stats.get("data_parallel"),
        "n_devices": engine_stats.get("n_devices"),
        "plan_cache": engine_stats.get("plan_cache"),
        "compile_cache": engine_stats.get("compile_cache"),
    }
    if extras:
        out.update(extras)
    return out


def energy_summary(name: str, op, executed, analytic,
                   extras: Optional[dict] = None) -> dict:
    """JSON-safe record of one executed-trace energy measurement.

    ``op`` is the OperatingPoint everything was derived from, ``executed``
    a core.hw.TraceEnergy from the executed plan, ``analytic`` the
    perf_model.InferenceResult predicted for the same network/hardware —
    the coherence evidence (their relative gap) rides along explicitly.
    """
    def rel(a, b):
        return abs(a - b) / max(abs(b), 1e-30)

    return {
        "kind": "energy",
        "name": name,
        "operating_point": op.describe(),
        "batch": executed.batch,
        "executed_fps": executed.fps,
        "executed_fps_per_watt": executed.fps_per_watt,
        "executed_energy_j": executed.energy_j,
        "executed_j_per_image": executed.j_per_image,
        "executed_watts": executed.watts,
        "analytic_fps": analytic.fps,
        "analytic_fps_per_watt": analytic.fps_per_watt,
        "analytic_energy_j": analytic.energy_j,
        "fps_rel_gap": rel(executed.fps, analytic.fps),
        "fpsw_rel_gap": rel(executed.fps_per_watt, analytic.fps_per_watt),
        **({} if not extras else dict(extras)),
    }


def render_report(summaries: Iterable[dict]) -> str:
    """Markdown table over plan summaries (one row per CNN/config)."""
    lines = [
        "| cnn | backend | batch | fps | fps/W | mix (os/is/ws) | "
        "cache h/m |",
        "|---|---|---|---|---|---|---|",
    ]
    for s in summaries:
        mix = s["dataflow_mix"]
        lines.append(
            f"| {s['name']} | {s['backend']} | {s['batch']} | "
            f"{s['fps']:.1f} | {s['fps_per_watt']:.2f} | "
            f"{mix.get('os', 0)}/{mix.get('is', 0)}/{mix.get('ws', 0)} | "
            f"{s['cache_hits']}/{s['cache_misses']} |")
    return "\n".join(lines)


def save_summary(summary: dict, directory: str, filename: str) -> str:
    """Write a summary JSON under ``directory`` (created if missing)."""
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, filename)
    with open(path, "w") as fh:
        json.dump(summary, fh, indent=1, sort_keys=True)
    return path

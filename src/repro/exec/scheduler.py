"""Per-layer dataflow auto-scheduler (the paper's "flexible dataflows").

HEANA's TAOM + BPCA combination lets *each layer* of a CNN run under OS,
IS, or WS instead of the single fixed dataflow of prior MRR accelerators
(paper §4, §6.3).  This module exploits that: given a CNN as a list of
im2col GEMMs (models.cnn.LayerGemm) and an AcceleratorConfig, it searches
per layer over {OS, IS, WS} x kernel tiling with the event-driven cost
model (core.perf_model.best_dataflow) and emits a LayerPlan per layer plus
whole-CNN totals.

Because every layer independently takes the argmin of the same cost model
a fixed dataflow would be charged with, the planned CNN latency is <= the
latency under ANY single fixed dataflow — the auto-schedule can only tie
or beat the best fixed choice (benchmarks/autoflow.py asserts this across
the whole CNN zoo at batch 1 and 256).

Tiling: dataflow choice is an analytic-model decision; the tiling choice
is an *executor* decision — which (block_m, block_d) output tile the
Pallas kernel should use for this layer's GEMM.  The search minimizes
padded-output waste, then grid steps; numerics are tile-invariant, so this
is purely a performance knob.

Plans are cached content-addressed (exec.plan_cache): repeated shapes and
configs — within one CNN, across CNNs, or across processes via
dump()/load() — skip the search entirely.

Hashability: TileChoice, LayerPlan and CnnPlan are hashable by value so
they can serve as *static* arguments to jax.jit — the executor's compiled
forward (exec.executor.forward_fn) bakes the plan's tilings into the
traced program, and jit's own cache keys on the plan.  LayerPlan freezes
its ``candidates`` mapping at construction; CnnPlan hashes on what
determines it (layers, accelerator, batch, objective) and excludes the
derived perf-model ``result``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

from repro.core import dataflow as df
from repro.core import hw
from repro.core import perf_model as pm
from repro.core.types import Dataflow
from repro.exec import plan_cache as pc
# The kernel's own tile constraints and rounding — imported, not copied,
# so choose_tile cannot drift from what taom_gemm_quantized actually runs.
from repro.kernels.taom_gemm import LANE as _LANE
from repro.kernels.taom_gemm import SUBLANE as _SUBLANE
from repro.kernels.taom_gemm import _round_up
from repro.models.cnn import LayerGemm

# Large-M tiles matter for executor throughput: the kernel's grid loop is
# serialized over M/block_m steps, so a batch-256 conv (M = 65536 rows)
# at block_m=256 pays 256 grid steps where block_m=4096 pays 16 — ~10x
# wall-clock on the serving hot path.  Padding waste still dominates the
# choice, so small layers keep small tiles; an (8, 4096) f32 block stays
# comfortably inside TPU VMEM budgets.
_BLOCK_M_CANDIDATES = (8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096)
_BLOCK_D_CANDIDATES = (128, 256)
# v3: depthwise (count>1, d=1) layers choose their tile for the GEMM the
# executor actually runs — the fused block-diagonal (M, kk*kk*C) @ (.., C)
# — instead of the analytic per-group (M, kk*kk) @ (.., 1) shape.
# v4: plans embed the hardware operating point (repro.core.hw.
# OperatingPoint): scheduling may accept an OperatingPoint directly, the
# CnnPlan carries it for the executor's kernel-cfg coherence check, and
# persisted entries are stamped with the format version so pre-v4 dumps
# cleanly invalidate on load (plan_cache.PLAN_FORMAT_VERSION).
_PLAN_VERSION = pc.PLAN_FORMAT_VERSION

#: What the scheduling entry points accept as "the hardware": a bare
#: AcceleratorConfig (legacy) or a full OperatingPoint (preferred — the
#: plan then pins the kernel config too).
HardwareSpec = Union[pm.AcceleratorConfig, hw.OperatingPoint]


def _resolve_hw(spec: HardwareSpec
                ) -> Tuple[pm.AcceleratorConfig, Optional[hw.OperatingPoint]]:
    if isinstance(spec, hw.OperatingPoint):
        return spec.accelerator_config(), spec
    return spec, None


class FrozenCandidates(dict):
    """Immutable, hashable dataflow -> modeled-latency mapping.

    A dict subclass so it stays JSON-serializable and keeps the plain
    ``plan.candidates["is"]`` read API, but with mutation blocked and a
    content hash — which is what lets LayerPlan (and through it CnnPlan)
    be a static jax.jit argument.
    """

    def __hash__(self) -> int:                       # type: ignore[override]
        return hash(tuple(sorted(self.items())))

    def _immutable(self, *args, **kw):
        raise TypeError("FrozenCandidates is immutable")

    __setitem__ = __delitem__ = _immutable
    clear = pop = popitem = setdefault = update = _immutable

    def __reduce__(self):
        # deepcopy/pickle rebuild through __init__ (C-level dict fill),
        # not item assignment, which is blocked.
        return (FrozenCandidates, (dict(self),))


@dataclasses.dataclass(frozen=True)
class TileChoice:
    """Kernel output-tile selection for one GEMM (executor knob)."""
    block_m: int
    block_d: int
    grid_m: int
    grid_d: int
    n_chunks: int          # temporal folds = ceil(K / DPE size)
    pad_waste: float       # padded-output overhead fraction (>= 0)


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """One layer's scheduled execution: dataflow + tiling + modeled cost."""
    name: str
    c: int                 # GEMM rows (batch already folded in)
    k: int
    d: int
    count: int             # parallel instances (depthwise groups)
    dataflow: Dataflow
    latency_s: float       # modeled, count included
    energy_j: float        # modeled (dynamic, no static share), count incl.
    candidates: Dict[str, float]   # dataflow value -> modeled latency (one
                                   # instance) for report/debugging
    tile: TileChoice
    cache_key: str
    # Run bookkeeping, not plan content: a plan served from the cache must
    # compare (and jit-cache) equal to the freshly searched one.
    cache_hit: bool = dataclasses.field(compare=False)

    def __post_init__(self):
        # Freeze the candidates mapping so the (frozen) dataclass hash —
        # required for static-jit use — is well defined.
        object.__setattr__(self, "candidates",
                           FrozenCandidates(self.candidates))

    @property
    def gemm(self) -> df.GemmShape:
        return df.GemmShape(self.c, self.k, self.d)


@dataclasses.dataclass(frozen=True, eq=False)
class CnnPlan:
    """A whole CNN's auto-scheduled execution plan.

    Hash/equality cover what *determines* the plan (layers, accelerator,
    batch, objective) — ``result`` is derived from those through the perf
    model and ``cache_hits``/``cache_misses`` are run bookkeeping, so two
    plans of the same problem compare equal (and hit the same jit trace)
    whether they came from the search or the plan cache.
    """
    layers: Tuple[LayerPlan, ...]
    acc: pm.AcceleratorConfig
    batch: int
    objective: str
    result: pm.InferenceResult     # perf-model totals under the plan
    cache_hits: int
    cache_misses: int
    # v4: the operating point the hardware was derived from, when the
    # plan was scheduled from one — lets the executor pin the kernel
    # config (bits/optics included) against the plan, and energy reports
    # carry full provenance.  None for legacy bare-AcceleratorConfig
    # plans (geometry-only coherence).
    op: Optional[hw.OperatingPoint] = None

    def _identity(self) -> tuple:
        return (self.layers, self.acc, self.batch, self.objective, self.op)

    def __eq__(self, other) -> bool:
        if not isinstance(other, CnnPlan):
            return NotImplemented
        return self._identity() == other._identity()

    def __hash__(self) -> int:
        return hash(self._identity())

    @property
    def dataflows(self) -> Tuple[Dataflow, ...]:
        return tuple(p.dataflow for p in self.layers)

    @property
    def latency_s(self) -> float:
        return self.result.latency_s

    @property
    def fps(self) -> float:
        return self.result.fps

    @property
    def fps_per_watt(self) -> float:
        return self.result.fps_per_watt

    def mix(self) -> Dict[str, int]:
        """How many layers landed on each dataflow."""
        out = {f.value: 0 for f in Dataflow}
        for p in self.layers:
            out[p.dataflow.value] += 1
        return out


def choose_tile(m: int, d: int, k: int, dpe_size: int) -> TileChoice:
    """Pick the kernel (block_m, block_d) for an (M, D) output.

    Minimize padded-output elements first (don't burn MXU cycles on
    padding), then grid steps (fewer, larger tiles win ties).  Mirrors the
    kernel's own clamping so grid numbers here are exactly what it runs.
    """
    best = None
    for bm in _BLOCK_M_CANDIDATES:
        bm_eff = min(bm, _round_up(m, _SUBLANE))
        for bd in _BLOCK_D_CANDIDATES:
            bd_eff = min(bd, _round_up(d, _LANE))
            mp, dp = _round_up(m, bm_eff), _round_up(d, bd_eff)
            grid_m, grid_d = mp // bm_eff, dp // bd_eff
            score = (mp * dp, grid_m * grid_d, bm_eff, bd_eff)
            if best is None or score < best[0]:
                waste = mp * dp / float(m * d) - 1.0
                best = (score, TileChoice(bm_eff, bd_eff, grid_m, grid_d,
                                          max(1, -(-k // dpe_size)), waste))
    return best[1]


def _cache_payload(g: df.GemmShape, count: int, acc: pm.AcceleratorConfig,
                   objective: str, flows: Sequence[Dataflow]) -> dict:
    return {
        "v": _PLAN_VERSION,
        "gemm": [g.c, g.k, g.d],
        "count": count,
        "acc": [acc.backend, acc.data_rate_gsps, acc.n, acc.m, acc.n_dpus],
        "objective": objective,
        "flows": sorted(f.value for f in flows),
        "tiles": [_BLOCK_M_CANDIDATES, _BLOCK_D_CANDIDATES],
    }


def _plan_to_dict(p: LayerPlan) -> dict:
    d = dataclasses.asdict(p)
    d["dataflow"] = p.dataflow.value
    d.pop("name")          # content-addressed: names don't enter the cache
    d.pop("cache_hit")
    d["plan_version"] = _PLAN_VERSION   # load-time invalidation stamp
    return d


def _plan_from_dict(d: dict, name: str, cache_hit: bool) -> LayerPlan:
    return LayerPlan(name=name, c=d["c"], k=d["k"], d=d["d"],
                     count=d["count"], dataflow=Dataflow(d["dataflow"]),
                     latency_s=d["latency_s"], energy_j=d["energy_j"],
                     candidates=dict(d["candidates"]),
                     tile=TileChoice(**d["tile"]),
                     cache_key=d["cache_key"], cache_hit=cache_hit)


def plan_layer(layer: LayerGemm, acc: HardwareSpec, batch: int = 1,
               objective: str = "latency",
               flows: Sequence[Dataflow] = tuple(Dataflow),
               cache: Optional[pc.PlanCache] = None) -> LayerPlan:
    """Schedule one layer: search dataflows x tiling, cache the result."""
    acc, _ = _resolve_hw(acc)
    cache = cache if cache is not None else pc.GLOBAL_PLAN_CACHE
    g = df.GemmShape(layer.c * batch, layer.k, layer.d)
    key = pc.fingerprint(_cache_payload(g, layer.count, acc, objective,
                                        flows))
    cached = cache.get(key)
    if cached is not None:
        return _plan_from_dict(cached, layer.name, cache_hit=True)

    flow, cost, costs = pm.best_dataflow(g, acc, flows, objective)
    # Dataflow cost is charged on the paper's analytic shape (count
    # grouped instances), but the tile must fit the GEMM the executor
    # actually runs — LayerGemm.executed owns that fusion convention
    # (depthwise groups fuse into one block-diagonal GEMM).
    em, ek, ed = LayerGemm(layer.name, g.c, g.k, g.d,
                           layer.count).executed
    tile = choose_tile(em, ed, ek, acc.n)
    plan = LayerPlan(
        name=layer.name, c=g.c, k=g.k, d=g.d, count=layer.count,
        dataflow=flow,
        latency_s=cost.latency_s * layer.count,
        energy_j=cost.energy.total * layer.count,
        candidates={f.value: c.latency_s for f, c in costs.items()},
        tile=tile, cache_key=key, cache_hit=False)
    cache.put(key, _plan_to_dict(plan))
    return plan


def schedule_cnn(layers: Iterable[LayerGemm], acc: HardwareSpec,
                 batch: int = 1, objective: str = "latency",
                 flows: Sequence[Dataflow] = tuple(Dataflow),
                 cache: Optional[pc.PlanCache] = None) -> CnnPlan:
    """Auto-schedule a whole CNN: per-layer dataflow + tiling plan.

    ``acc`` is either a bare AcceleratorConfig (legacy) or an
    OperatingPoint (preferred): an OperatingPoint is resolved to its
    ``accelerator_config()`` for the search AND embedded in the returned
    plan, so the executor can verify the kernel config against the
    hardware the plan was actually scheduled for (plan v4).

    The returned plan's ``result`` holds the perf-model totals (FPS,
    FPS/W, latency, energy incl. static) under the mixed dataflows —
    computed by the same core.perf_model.cnn_inference everything else in
    the repo uses, so planned numbers are directly comparable to the
    fixed-dataflow figures of Figs. 11-14.
    """
    acc, op = _resolve_hw(acc)
    cache = cache if cache is not None else pc.GLOBAL_PLAN_CACHE
    layers = list(layers)
    plans: List[LayerPlan] = [
        plan_layer(layer, acc, batch, objective, flows, cache)
        for layer in layers]
    # Plan totals at the operating point's optics (default optics for
    # legacy plans) — the per-layer search itself stays at default
    # optics (dataflow_costs: the plan cache keys on the accelerator
    # config alone), so LayerPlan.energy_j is a default-optics figure;
    # ``result`` and hw.trace_energy are the op-coherent totals.
    result = pm.cnn_inference(layers, acc, batch,
                              dataflows=[p.dataflow for p in plans],
                              optics=op.optics if op is not None else None)
    hits = sum(1 for p in plans if p.cache_hit)
    return CnnPlan(layers=tuple(plans), acc=acc, batch=batch,
                   objective=objective, result=result,
                   cache_hits=hits, cache_misses=len(plans) - hits, op=op)


def schedule_buckets(layers: Iterable[LayerGemm], acc: HardwareSpec,
                     batches: Sequence[int], objective: str = "latency",
                     flows: Sequence[Dataflow] = tuple(Dataflow),
                     cache: Optional[pc.PlanCache] = None,
                     ) -> Dict[int, CnnPlan]:
    """Schedule one network at several batch sizes (the serving buckets).

    The batched serving engine (exec.serving) plans every power-of-two
    bucket ahead of time; this keeps all of a network's bucket plans on
    one shared plan cache, so layers whose batched GEMM shape repeats
    across buckets (the fc layer, depthwise groups) hit instead of
    re-searching.  Returns {batch: CnnPlan} in the given bucket order.
    """
    cache = cache if cache is not None else pc.GLOBAL_PLAN_CACHE
    layers = list(layers)
    return {int(b): schedule_cnn(layers, acc, batch=int(b),
                                 objective=objective, flows=flows,
                                 cache=cache)
            for b in batches}

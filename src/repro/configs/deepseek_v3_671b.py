"""deepseek-v3-671b [moe]: 61L d_model=7168 128H d_ff(expert)=2048
vocab=129280, MoE 256e top-8 — MLA, 1 shared + 256 routed, MTP
[arXiv:2412.19437; hf].  First 3 layers dense (d_ff 18432)."""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig, register

FULL = ArchConfig(
    name="deepseek-v3-671b", family="moe", num_layers=61, d_model=7168,
    num_heads=128, num_kv_heads=128, d_ff=18432, vocab_size=129280,
    rope_theta=1e4,
    moe=MoEConfig(num_experts=256, experts_per_token=8,
                  num_shared_experts=1, d_ff_expert=2048,
                  first_dense_layers=3),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, qk_rope_dim=64,
                  qk_nope_dim=128, v_head_dim=128),
    mtp_depth=1)

SMOKE = ArchConfig(
    name="deepseek-v3-671b", family="moe", num_layers=4, d_model=64,
    num_heads=4, num_kv_heads=4, d_ff=192, vocab_size=512,
    rope_theta=1e4,
    moe=MoEConfig(num_experts=8, experts_per_token=2,
                  num_shared_experts=1, d_ff_expert=32,
                  first_dense_layers=2),
    mla=MLAConfig(kv_lora_rank=32, q_lora_rank=48, qk_rope_dim=16,
                  qk_nope_dim=32, v_head_dim=32),
    mtp_depth=1)

register(FULL, SMOKE)

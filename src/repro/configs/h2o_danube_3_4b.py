"""h2o-danube-3-4b [dense]: 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000 — llama+mistral mix, SWA [arXiv:2401.16818; unverified].
Sliding window 4096 (mistral-style) -> long_500k cell runs with a
windowed cache."""
from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="h2o-danube-3-4b", family="dense", num_layers=24, d_model=3840,
    num_heads=32, num_kv_heads=8, d_ff=10240, vocab_size=32000,
    head_dim=120, rope_theta=1e4, sliding_window=4096)

SMOKE = ArchConfig(
    name="h2o-danube-3-4b", family="dense", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, d_ff=160, vocab_size=512,
    head_dim=16, rope_theta=1e4, sliding_window=16)

register(FULL, SMOKE)

"""gemma3-12b [dense]: 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144 — 5:1 local:global, 128k [hf:google/gemma-3-1b-pt;
unverified].  Period-6 superblocks (5 x window-1024 local + 1 global);
long_500k runs (local layers windowed, global layers full cache)."""
from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="gemma3-12b", family="dense", num_layers=48, d_model=3840,
    num_heads=16, num_kv_heads=8, d_ff=15360, vocab_size=262144,
    head_dim=240, rope_theta=1e6, local_global_period=6, local_window=1024)

SMOKE = ArchConfig(
    name="gemma3-12b", family="dense", num_layers=6, d_model=64,
    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=512,
    head_dim=16, rope_theta=1e6, local_global_period=6, local_window=8)

register(FULL, SMOKE)

"""deepseek-v2-236b [moe]: 60L d_model=5120 128H d_ff(expert)=1536
vocab=102400, MoE 160e top-6 — MLA kv_lora=512, 2 shared + 160 routed
[arXiv:2405.04434; hf].  First layer dense (d_ff 12288)."""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig, register

FULL = ArchConfig(
    name="deepseek-v2-236b", family="moe", num_layers=60, d_model=5120,
    num_heads=128, num_kv_heads=128, d_ff=12288, vocab_size=102400,
    rope_theta=1e4,
    moe=MoEConfig(num_experts=160, experts_per_token=6,
                  num_shared_experts=2, d_ff_expert=1536,
                  first_dense_layers=1),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, qk_rope_dim=64,
                  qk_nope_dim=128, v_head_dim=128))

SMOKE = ArchConfig(
    name="deepseek-v2-236b", family="moe", num_layers=3, d_model=64,
    num_heads=4, num_kv_heads=4, d_ff=160, vocab_size=512,
    rope_theta=1e4,
    moe=MoEConfig(num_experts=8, experts_per_token=2,
                  num_shared_experts=2, d_ff_expert=32,
                  first_dense_layers=1),
    mla=MLAConfig(kv_lora_rank=32, q_lora_rank=48, qk_rope_dim=16,
                  qk_nope_dim=32, v_head_dim=32))

register(FULL, SMOKE)

"""Config registry: import every arch module to populate the registry."""
from repro.configs import (deepseek_v2_236b, deepseek_v3_671b, gemma3_12b,
                           h2o_danube_3_4b, llava_next_mistral_7b,
                           mamba2_130m, qwen2_0_5b, qwen2_1_5b, whisper_tiny,
                           zamba2_7b)  # noqa: F401
from repro.configs.base import (SHAPES, ArchConfig, RunShape,
                                cell_is_supported, get_config, list_archs)

__all__ = ["SHAPES", "ArchConfig", "RunShape", "cell_is_supported",
           "get_config", "list_archs"]

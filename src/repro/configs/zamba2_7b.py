"""zamba2-7b [hybrid]: 81L d_model=3584 32H (shared attn) d_ff=14336
vocab=32000, ssm_state=64 — Mamba2 + shared attention blocks
[arXiv:2411.15242; unverified].  One shared attention block (shared
params, per-position KV cache) every 6 mamba blocks."""
from repro.configs.base import ArchConfig, SSMConfig, register

FULL = ArchConfig(
    name="zamba2-7b", family="hybrid", num_layers=81, d_model=3584,
    num_heads=32, num_kv_heads=32, d_ff=14336, vocab_size=32000,
    head_dim=112, shared_attn_period=6,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, n_groups=1,
                  conv_width=4, chunk=128))

SMOKE = ArchConfig(
    name="zamba2-7b", family="hybrid", num_layers=7, d_model=64,
    num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=512,
    head_dim=16, shared_attn_period=3,
    ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, n_groups=1,
                  conv_width=4, chunk=8))

register(FULL, SMOKE)

"""mamba2-130m [ssm]: 24L d_model=768 (attn-free) vocab=50280,
ssm_state=128 — SSD [arXiv:2405.21060; unverified]."""
from repro.configs.base import ArchConfig, SSMConfig, register

FULL = ArchConfig(
    name="mamba2-130m", family="ssm", num_layers=24, d_model=768,
    num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=50280,
    tie_embeddings=True,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, n_groups=1,
                  conv_width=4, chunk=128))

SMOKE = ArchConfig(
    name="mamba2-130m", family="ssm", num_layers=2, d_model=64,
    num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=512,
    tie_embeddings=True,
    ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, n_groups=1,
                  conv_width=4, chunk=8))

register(FULL, SMOKE)

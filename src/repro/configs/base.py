"""Architecture + run-shape configuration system.

One ``ArchConfig`` per assigned architecture lives in ``configs/<id>.py``
(exact numbers from the assignment table).  Every config also provides a
``smoke()`` reduction — same family/wiring, tiny dims — used by the per-arch
CPU smoke tests.  ``SHAPES`` defines the four assigned input shapes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    experts_per_token: int
    num_shared_experts: int = 0
    d_ff_expert: int = 0            # per-expert FFN width
    first_dense_layers: int = 0     # leading layers with dense FFN
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 0            # 0 = no query compression
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128
    head_dim: int = 64
    expand: int = 2
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | audio | vlm | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads
    qkv_bias: bool = False
    rope_theta: float = 1e6
    tie_embeddings: bool = False
    # attention pattern
    sliding_window: int = 0         # 0 = full attention everywhere
    local_global_period: int = 0    # gemma3: 6 (5 local + 1 global)
    local_window: int = 1024
    # family sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): a shared attention block every k mamba blocks
    shared_attn_period: int = 0
    # enc-dec (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 1500
    # vlm (llava): image tokens prepended as precomputed patch embeddings
    num_image_tokens: int = 0
    vision_embed_dim: int = 0
    # MTP (deepseek-v3 multi-token prediction) depth
    mtp_depth: int = 0
    # §Perf: pad the q-head count up to a multiple of this so attention
    # tensors shard cleanly on the production model axis (16).  Dead heads
    # are hard-masked — semantics remain exactly ``num_heads`` heads.
    head_pad: int = 1
    dtype: str = "bfloat16"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_subquadratic(self) -> bool:
        """Can this arch serve a 500k-token context without a dense
        full-attention cache?  (SSM state, or windowed attention with at
        most a bounded number of global layers.)"""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window > 0 or self.local_global_period > 0


@dataclasses.dataclass(frozen=True)
class RunShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: Dict[str, RunShape] = {
    "train_4k": RunShape("train_4k", 4096, 256, "train"),
    "prefill_32k": RunShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": RunShape("decode_32k", 32768, 128, "decode"),
    "long_500k": RunShape("long_500k", 524288, 1, "decode"),
}


_REGISTRY: Dict[str, Tuple["ArchConfig", "ArchConfig"]] = {}


def register(full: ArchConfig, smoke: ArchConfig) -> ArchConfig:
    _REGISTRY[full.name] = (full, smoke)
    return full


def get_config(name: str, smoke: bool = False) -> ArchConfig:
    import repro.configs  # noqa: F401  (triggers per-arch module imports)
    full, small = _REGISTRY[name]
    return small if smoke else full


def list_archs() -> Tuple[str, ...]:
    import repro.configs  # noqa: F401
    return tuple(sorted(_REGISTRY))


def cell_is_supported(cfg: ArchConfig, shape: RunShape) -> Tuple[bool, str]:
    """Whether an (arch x shape) cell runs, and why not if it doesn't.

    Per the assignment: long_500k requires sub-quadratic attention — pure
    full-attention archs skip it (documented in DESIGN.md §4).
    """
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, ("full-attention arch: 500k dense KV cache is "
                       "architecturally unsupported (DESIGN.md §4)")
    return True, ""

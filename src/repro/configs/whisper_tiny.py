"""whisper-tiny [audio]: 4L d_model=384 6H d_ff=1536 vocab=51865 —
enc-dec, conv frontend STUB [arXiv:2212.04356; unverified]."""
from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="whisper-tiny", family="audio", num_layers=4, d_model=384,
    num_heads=6, num_kv_heads=6, d_ff=1536, vocab_size=51865,
    head_dim=64, encoder_layers=4, encoder_seq=1500, head_pad=16)

SMOKE = ArchConfig(
    name="whisper-tiny", family="audio", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=512,
    head_dim=16, encoder_layers=2, encoder_seq=24)

register(FULL, SMOKE)

"""qwen2-1.5b [dense]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936 — GQA, QKV bias [arXiv:2407.10671; hf]."""
from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="qwen2-1.5b", family="dense", num_layers=28, d_model=1536,
    num_heads=12, num_kv_heads=2, d_ff=8960, vocab_size=151936,
    head_dim=128, qkv_bias=True, rope_theta=1e6, tie_embeddings=True, head_pad=16)

SMOKE = ArchConfig(
    name="qwen2-1.5b", family="dense", num_layers=2, d_model=96,
    num_heads=4, num_kv_heads=2, d_ff=192, vocab_size=512,
    head_dim=24, qkv_bias=True, rope_theta=1e6, tie_embeddings=True)

register(FULL, SMOKE)

"""llava-next-mistral-7b [vlm]: 32L d_model=4096 32H (GQA kv=8)
d_ff=14336 vocab=32000 — anyres tiling (patch embeddings STUB)
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]."""
from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="llava-next-mistral-7b", family="vlm", num_layers=32,
    d_model=4096, num_heads=32, num_kv_heads=8, d_ff=14336,
    vocab_size=32000, head_dim=128, rope_theta=1e6,
    num_image_tokens=2880, vision_embed_dim=1024)

SMOKE = ArchConfig(
    name="llava-next-mistral-7b", family="vlm", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=512,
    head_dim=16, rope_theta=1e6, num_image_tokens=6, vision_embed_dim=32)

register(FULL, SMOKE)

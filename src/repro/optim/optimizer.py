"""AdamW + schedules, built directly on pytrees (no optax dependency).

Optimizer state is a pytree mirroring the params (fp32 m/v), so the same
sharding rules apply — and launch/train.py additionally ZeRO-1 shards the
moments over the data axis (see zero1_shardings).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jnp.ndarray       # scalar int32
    m: dict                 # fp32, like params
    v: dict                 # fp32, like params


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    decayed = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, decayed)


def init(params) -> AdamState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamState(jnp.zeros((), jnp.int32), zeros,
                     jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def apply(cfg: AdamWConfig, params, state: AdamState, grads,
          lr_scale: float = 1.0):
    """One AdamW update.  Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = lr_schedule(cfg, step) * lr_scale
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m2 / b1c
        vhat = v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamState(step, new_m, new_v), metrics

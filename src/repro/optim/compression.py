"""Gradient compression for cross-pod all-reduce (distributed-opt trick).

int8 quantized all-reduce with error feedback: gradients are symmetrically
quantized per-tensor to int8 before the (pod-axis) all-reduce, and the
quantization residual is carried to the next step (error feedback keeps
SGD/Adam convergence — Karimireddy et al. 2019).  Crossing the pod axis is
the slow link at 512+ chips, so an 8x byte reduction there is the win; the
in-pod reduction stays full precision.

Exposed as a pure pytree transform so it composes with any optimizer:

    cg, state = compress_grads(grads, state)       # before all-reduce
    grads     = decompress_grads(cg)               # after

plus ``allreduce_compressed`` which fuses the pattern under shard_map for
the launcher.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class CompressedTensor(NamedTuple):
    q: jnp.ndarray        # int8
    scale: jnp.ndarray    # f32 scalar


class ErrorFeedbackState(NamedTuple):
    residual: dict        # like grads, f32


def init_state(grads) -> ErrorFeedbackState:
    return ErrorFeedbackState(
        jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads))


def _compress_one(g: jnp.ndarray, r: jnp.ndarray
                  ) -> Tuple[CompressedTensor, jnp.ndarray]:
    gf = g.astype(jnp.float32) + r
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    residual = gf - q.astype(jnp.float32) * scale
    return CompressedTensor(q, scale), residual


def compress_grads(grads, state: ErrorFeedbackState
                   ) -> Tuple[dict, ErrorFeedbackState]:
    pairs = jax.tree.map(_compress_one, grads, state.residual,
                         is_leaf=lambda x: isinstance(x, jnp.ndarray))
    is_pair = lambda t: isinstance(t, tuple) and len(t) == 2 and \
        isinstance(t[0], CompressedTensor)  # noqa: E731
    comp = jax.tree.map(lambda t: t[0], pairs, is_leaf=is_pair)
    res = jax.tree.map(lambda t: t[1], pairs, is_leaf=is_pair)
    return comp, ErrorFeedbackState(res)


def decompress_grads(comp) -> dict:
    return jax.tree.map(
        lambda c: c.q.astype(jnp.float32) * c.scale,
        comp, is_leaf=lambda x: isinstance(x, CompressedTensor))


def allreduce_compressed(grads, state: ErrorFeedbackState, axis_name: str
                         ) -> Tuple[dict, ErrorFeedbackState]:
    """Inside shard_map: quantized ring all-reduce over ``axis_name``.

    Wire format: int16 reduce-scatter (exact — 127 * P fits int16 for up to
    P=256 pods) followed by an int8 all-gather of the re-quantized local
    chunk.  Bytes/element on the slow link: 2 (RS) + 1 (AG) = 3, vs 8 for
    the f32 ring (4 + 4) — a 2.7x cut, measured in the compiled HLO by
    EXPERIMENTS.md §Perf.  The all-gather requantization error is absorbed
    by the next step's error feedback together with the first-stage
    residual.
    """
    comp, new_state = compress_grads(grads, state)
    n = jax.lax.psum(1, axis_name)

    def reduce_one(c: CompressedTensor) -> jnp.ndarray:
        shape = c.q.shape
        flat = c.q.reshape(-1)
        pad = (-flat.size) % n
        if pad:
            flat = jnp.pad(flat, (0, pad))
        # exact int16 reduce-scatter of the int8 payloads
        chunk = jax.lax.psum_scatter(flat.astype(jnp.int16), axis_name,
                                     scatter_dimension=0, tiled=True)
        scale = jax.lax.pmean(c.scale, axis_name)
        chunk_f = chunk.astype(jnp.float32) * scale / n
        # re-quantize the reduced chunk to int8 for the all-gather
        cscale = jnp.maximum(jnp.max(jnp.abs(chunk_f)), 1e-12) / 127.0
        cq = jnp.clip(jnp.round(chunk_f / cscale), -127, 127) \
            .astype(jnp.int8)
        full = jax.lax.all_gather(cq, axis_name, tiled=True)
        scales = jax.lax.all_gather(cscale, axis_name)
        per_chunk = full.reshape(n, -1).astype(jnp.float32) * \
            scales[:, None]
        out = per_chunk.reshape(-1)
        if pad:
            out = out[:-pad]
        return out.reshape(shape)

    reduced = jax.tree.map(reduce_one, comp,
                           is_leaf=lambda x: isinstance(x, CompressedTensor))
    return reduced, new_state


def compression_ratio(grads) -> float:
    """Bytes(f32 grads) / bytes(int8 payload + scales)."""
    f32 = sum(g.size * 4 for g in jax.tree.leaves(grads))
    q = sum(g.size + 4 for g in jax.tree.leaves(grads))
    return f32 / q

"""Atomic, restart-safe distributed checkpointing.

Layout (one directory per step):
    <root>/step_000120.tmp/        # staged writes
        manifest.json              # tree structure, shapes, dtypes, step
        arrays.npz                 # flat param/opt tensors (host-gathered)
    <root>/step_000120/            # atomic rename after fsync

Guarantees:
  * atomicity — a checkpoint either fully exists or not at all (tmp dir +
    os.replace); a crash mid-save never corrupts the latest good step;
  * resumability — ``latest_step``/``restore`` pick up the newest complete
    checkpoint, and the data pipeline's statelessness makes the resumed
    run bit-identical;
  * integrity — manifest records per-array checksums, verified on restore;
  * retention — keep_last N (default 3) with the best-loss step pinned.

On a real multi-host cluster each host would write its local shards
(process-local jax.Array pieces); here the single process fully gathers.
The interface is the same either way.
"""
from __future__ import annotations

import json
import os
import shutil
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree, upcast: bool = True) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if upcast and (arr.dtype.kind == "V" or
                       "bfloat16" in str(arr.dtype)):
            # np.savez stores bf16 as raw void bytes it can't cast back —
            # store losslessly upcast, restore() casts to the template.
            arr = np.asarray(jax.numpy.asarray(arr).astype(jax.numpy.float32))
        flat[key] = arr
    return flat


def save(root: str, step: int, tree: Any, extra: Optional[dict] = None
         ) -> str:
    os.makedirs(root, exist_ok=True)
    final = os.path.join(root, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {
        "step": step,
        "extra": extra or {},
        "treedef": str(jax.tree_util.tree_structure(tree)),
        "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype),
                       "crc32": zlib.crc32(v.tobytes()) & 0xFFFFFFFF}
                   for k, v in flat.items()},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)           # atomic publish
    return final


def latest_step(root: str) -> Optional[int]:
    if not os.path.isdir(root):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(root)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(root, d, "manifest.json"))]
    return max(steps) if steps else None


def restore(root: str, template: Any, step: Optional[int] = None,
            verify: bool = True) -> Tuple[Any, dict]:
    """Restore into the structure of ``template`` (shapes must match)."""
    step = latest_step(root) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {root}")
    d = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))
    flat_template = _flatten(template, upcast=False)
    restored = {}
    for k, tmpl in flat_template.items():
        arr = data[k]
        if verify:
            want = manifest["arrays"][k]["crc32"]
            got = zlib.crc32(arr.tobytes()) & 0xFFFFFFFF
            if want != got:
                raise IOError(f"checksum mismatch for {k} in step {step}")
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(f"shape mismatch {k}: ckpt {arr.shape} vs "
                             f"template {tmpl.shape}")
        restored[k] = np.asarray(
            jax.numpy.asarray(arr).astype(tmpl.dtype))
    leaves, treedef = jax.tree_util.tree_flatten(template)
    keys = list(flat_template.keys())
    new_leaves = [restored[k] for k in keys]
    tree = jax.tree_util.tree_unflatten(treedef, new_leaves)
    return tree, manifest


def retain(root: str, keep_last: int = 3,
           pin_step: Optional[int] = None) -> None:
    """Delete all but the newest ``keep_last`` checkpoints (+ pinned)."""
    if not os.path.isdir(root):
        return
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(root)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    doomed = steps[:-keep_last] if keep_last else steps
    for s in doomed:
        if pin_step is not None and s == pin_step:
            continue
        shutil.rmtree(os.path.join(root, f"step_{s:08d}"), ignore_errors=True)

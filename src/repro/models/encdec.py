"""Whisper-style encoder-decoder backbone (audio family).

Per the assignment's modality-stub contract, the conv frontend is a STUB:
``encode`` consumes precomputed frame embeddings (B, frames, d_model-ready
features are projected in).  Everything downstream — bidirectional encoder,
causal decoder with cross-attention, serving caches — is real.

Whisper details kept: learned positional embeddings (no RoPE), GELU MLPs,
LayerNorm (not RMSNorm), pre-norm blocks.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models.transformer import make_stacked


def _spec(cfg: ArchConfig, causal: bool, use_rope: bool = False) -> A.AttnSpec:
    return A.AttnSpec(d_model=cfg.d_model, num_heads=cfg.num_heads,
                      num_kv_heads=cfg.num_kv_heads,
                      head_dim=cfg.resolved_head_dim, causal=causal,
                      use_rope=use_rope, qkv_bias=True)


def init_params(cfg: ArchConfig, key: Optional[jax.Array],
                abstract: bool = False) -> dict:
    maker = L.ParamMaker(key, dtype=jnp.dtype(cfg.dtype), abstract=abstract)
    d = cfg.d_model

    def enc_block(mk, nm):
        return {"ln1": L.make_layer_norm(mk, f"{nm}.ln1", d),
                "attn": A.make_attention(mk, f"{nm}.attn", _spec(cfg, False)),
                "ln2": L.make_layer_norm(mk, f"{nm}.ln2", d),
                "ffn": L.make_mlp(mk, f"{nm}.ffn", d, cfg.d_ff, gated=False)}

    def dec_block(mk, nm):
        return {"ln1": L.make_layer_norm(mk, f"{nm}.ln1", d),
                "self_attn": A.make_attention(mk, f"{nm}.self",
                                              _spec(cfg, True)),
                "ln_x": L.make_layer_norm(mk, f"{nm}.lnx", d),
                "cross_attn": A.make_attention(mk, f"{nm}.cross",
                                               _spec(cfg, False)),
                "ln2": L.make_layer_norm(mk, f"{nm}.ln2", d),
                "ffn": L.make_mlp(mk, f"{nm}.ffn", d, cfg.d_ff, gated=False)}

    return {
        "frame_proj": L.make_dense(maker, "frame_proj",
                                   cfg.vision_embed_dim or 80, d,
                                   (None, L.EMBED)),
        "enc_pos": maker.param("enc_pos", (cfg.encoder_seq, d),
                               (None, L.EMBED), scale=0.02),
        "encoder": make_stacked(maker, "encoder", cfg.encoder_layers,
                                enc_block),
        "enc_ln": L.make_layer_norm(maker, "enc_ln", d),
        "embed": L.make_embedding(maker, "embed", cfg.vocab_size, d),
        # sized for the assigned decode_32k cell (cache 32768 + headroom);
        # real Whisper caps at 448 target positions (DESIGN.md §4)
        "dec_pos": maker.param("dec_pos", (33024, d), (None, L.EMBED),
                               scale=0.02),
        "decoder": make_stacked(maker, "decoder", cfg.num_layers, dec_block),
        "dec_ln": L.make_layer_norm(maker, "dec_ln", d),
    }


def param_specs(cfg: ArchConfig) -> dict:
    return init_params(cfg, key=None)


def encode(params: dict, frames: jnp.ndarray, cfg: ArchConfig,
           ctx: L.PhotonicCtx = L.EXACT_CTX) -> jnp.ndarray:
    """frames: (B, T_frames, feat) precomputed frontend features (STUB)."""
    b, t, _ = frames.shape
    x = L.dense(params["frame_proj"], frames, ctx, "frame_proj")
    x = x + params["enc_pos"][:t][None].astype(x.dtype)
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    spec = _spec(cfg, causal=False)

    def block(x, p):
        h, _ = A.attention(p["attn"], L.layer_norm(p["ln1"], x), positions,
                           spec, ctx, "enc.attn")
        x = x + h
        x = x + L.mlp(p["ffn"], L.layer_norm(p["ln2"], x), ctx, "enc.ffn",
                      act=jax.nn.gelu)
        return x, None

    # Whisper stacks are tiny (4 layers) — unroll so dry-run cost analysis
    # is exact (XLA counts scan bodies once; see transformer._scan_group).
    for i in range(cfg.encoder_layers):
        x, _ = block(x, jax.tree.map(lambda a, i=i: a[i],
                                     params["encoder"]))
    return L.layer_norm(params["enc_ln"], x)


def _decoder_pass(params, tokens, positions, enc_out, cfg, ctx,
                  caches=None, cache_index=None):
    b, s = tokens.shape
    x = L.embed(params["embed"], tokens)
    x = x + jnp.take(params["dec_pos"], positions, axis=0).astype(x.dtype)
    self_spec = _spec(cfg, causal=True)
    cross_spec = _spec(cfg, causal=False)

    def block(x, p, cache):
        c = cache["self"] if cache is not None else None
        h, nc = A.attention(p["self_attn"], L.layer_norm(p["ln1"], x),
                            positions, self_spec, ctx, "dec.self",
                            c, cache_index)
        x = x + h
        h, _ = A.attention(p["cross_attn"], L.layer_norm(p["ln_x"], x),
                           positions, cross_spec, ctx, "dec.cross",
                           kv_source=enc_out)
        x = x + h
        x = x + L.mlp(p["ffn"], L.layer_norm(p["ln2"], x), ctx, "dec.ffn",
                      act=jax.nn.gelu)
        return x, ({"self": nc} if nc is not None else None)

    ncs = []
    for i in range(cfg.num_layers):
        pick = lambda a, i=i: a[i]  # noqa: E731
        p_i = jax.tree.map(pick, params["decoder"])
        c_i = jax.tree.map(pick, caches) if caches is not None else None
        x, nc = block(x, p_i, c_i)
        ncs.append(nc)
    new_caches = (jax.tree.map(lambda *a: jnp.stack(a), *ncs)
                  if ncs and ncs[-1] is not None else None)
    x = L.layer_norm(params["dec_ln"], x)
    return L.unembed(params["embed"], x, ctx), new_caches


def forward(params: dict, tokens: jnp.ndarray, frames: jnp.ndarray,
            cfg: ArchConfig, ctx: L.PhotonicCtx = L.EXACT_CTX
            ) -> jnp.ndarray:
    """Teacher-forced training pass: (B,S) tokens + (B,T,feat) frames."""
    b, s = tokens.shape
    enc_out = encode(params, frames, cfg, ctx)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    logits, _ = _decoder_pass(params, tokens, positions, enc_out, cfg, ctx)
    return logits


def init_caches(cfg: ArchConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16) -> dict:
    spec = _spec(cfg, causal=True)
    one = {"self": A.init_cache(spec, batch, max_len, dtype)}
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.num_layers,) + a.shape), one)


def prefill(params: dict, tokens: jnp.ndarray, frames: jnp.ndarray,
            cfg: ArchConfig, caches: dict,
            ctx: L.PhotonicCtx = L.EXACT_CTX) -> Tuple[jnp.ndarray, dict,
                                                       jnp.ndarray]:
    b, s = tokens.shape
    enc_out = encode(params, frames, cfg, ctx)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    logits, new_caches = _decoder_pass(params, tokens, positions, enc_out,
                                       cfg, ctx, caches, cache_index=None)
    return logits[:, -1:], new_caches, enc_out


def decode_step(params: dict, token: jnp.ndarray, index: jnp.ndarray,
                enc_out: jnp.ndarray, cfg: ArchConfig, caches: dict,
                ctx: L.PhotonicCtx = L.EXACT_CTX) -> Tuple[jnp.ndarray, dict]:
    b = token.shape[0]
    positions = jnp.full((b, 1), index, jnp.int32)
    return _decoder_pass(params, token, positions, enc_out, cfg, ctx,
                         caches, cache_index=index)

"""Base layers: parameter construction, photonic-routable dense, norms, RoPE.

Parameters are plain nested dicts of jnp arrays.  ``ParamMaker`` builds them
AND their logical sharding axes from a single code path:

    maker = ParamMaker(key)          -> arrays (init mode)
    maker = ParamMaker(None)         -> logical-axis tuples (spec mode)
    maker = ParamMaker(key, abstract=True) -> ShapeDtypeStructs (dry-run)

so the param tree and its PartitionSpec tree can never drift apart.

``dense`` is the paper integration point: every projection in the zoo goes
through it, and a ``PhotonicCtx`` reroutes the matmul through the HEANA /
AMW / MAW numerics simulation (kernels.ops.photonic_matmul) — the paper's
technique as a first-class numerics backend for any architecture.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.types import Backend, PhotonicConfig

# Logical axis names (mapped to mesh axes in parallel/sharding.py).
EMBED = "embed"      # d_model           -> replicated (activations row dim)
MLP = "mlp"          # FFN hidden        -> model
HEADS = "heads"      # attention heads   -> model
KV_HEADS = "kv_heads"  # kv heads        -> model (or replicated if few)
VOCAB = "vocab"      # vocabulary        -> model
EXPERT = "expert"    # MoE experts       -> model (expert parallelism)
SSM_INNER = "ssm_inner"  # mamba inner   -> model
STACK = "stack"      # scanned layer stack -> replicated
NONE = None


class ParamMaker:
    """Builds param trees (arrays / specs / abstract) from one code path."""

    def __init__(self, key: Optional[jax.Array], dtype=jnp.bfloat16,
                 abstract: bool = False):
        self.key = key
        self.dtype = dtype
        self.abstract = abstract

    @property
    def spec_mode(self) -> bool:
        return self.key is None

    def _fold(self, name: str) -> jax.Array:
        return jax.random.fold_in(self.key, zlib.crc32(name.encode()))

    def param(self, name: str, shape: Sequence[int], axes: Tuple,
              init: str = "normal", scale: Optional[float] = None):
        assert len(axes) == len(shape), (name, shape, axes)
        if self.spec_mode:
            return axes
        if self.abstract:
            return jax.ShapeDtypeStruct(tuple(shape), self.dtype)
        if init == "zeros":
            return jnp.zeros(shape, self.dtype)
        if init == "ones":
            return jnp.ones(shape, self.dtype)
        fan_in = shape[0] if len(shape) > 1 else max(shape[0], 1)
        if init == "embed":
            fan_in = 1.0
        s = scale if scale is not None else fan_in ** -0.5
        return (jax.random.normal(self._fold(name), tuple(shape), jnp.float32)
                * s).astype(self.dtype)


@dataclasses.dataclass(frozen=True)
class PhotonicCtx:
    """Routes zoo matmuls through the photonic numerics simulation.

    cfg=None or backend=EXACT -> plain XLA matmul.  ``key`` enables the
    detection-noise draw; each call site folds in its name so layers get
    independent noise.  ``impl`` picks the Pallas kernel or jnp oracle.
    """
    cfg: Optional[PhotonicConfig] = None
    key: Optional[jax.Array] = None
    impl: str = "ref"

    @property
    def active(self) -> bool:
        return self.cfg is not None and self.cfg.backend != Backend.EXACT

    def site_key(self, name: str) -> Optional[jax.Array]:
        if self.key is None:
            return None
        return jax.random.fold_in(self.key, zlib.crc32(name.encode()))


EXACT_CTX = PhotonicCtx()


def dense(params, x: jnp.ndarray, ctx: PhotonicCtx = EXACT_CTX,
          name: str = "dense") -> jnp.ndarray:
    """(..., K) @ w[K, D] (+ b) — photonic-routable."""
    w = params["w"]
    if ctx.active:
        from repro.kernels import ops as kops
        out = kops.photonic_matmul(x, w, ctx.cfg, key=ctx.site_key(name),
                                   impl=ctx.impl)
    else:
        out = x @ w
    if "b" in params:
        out = out + params["b"]
    return out


def make_dense(maker: ParamMaker, name: str, d_in: int, d_out: int,
               axes: Tuple = (EMBED, MLP), bias: bool = False,
               scale: Optional[float] = None) -> dict:
    p = {"w": maker.param(f"{name}.w", (d_in, d_out), axes, scale=scale)}
    if bias:
        p["b"] = maker.param(f"{name}.b", (d_out,), (axes[1],), init="zeros")
    return p


def rms_norm(scale: jnp.ndarray, x: jnp.ndarray,
             eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def make_rms_norm(maker: ParamMaker, name: str, dim: int) -> jnp.ndarray:
    return maker.param(f"{name}.scale", (dim,), (EMBED,), init="zeros")


def layer_norm(params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["g"].astype(jnp.float32) +
            params["b"].astype(jnp.float32)).astype(dt)


def make_layer_norm(maker: ParamMaker, name: str, dim: int) -> dict:
    return {"g": maker.param(f"{name}.g", (dim,), (EMBED,), init="ones"),
            "b": maker.param(f"{name}.b", (dim,), (EMBED,), init="zeros")}


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (...,S,hd/2)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embeddings / heads
# ---------------------------------------------------------------------------
def make_embedding(maker: ParamMaker, name: str, vocab: int,
                   dim: int) -> dict:
    # GPT-style 0.02 init keeps tied-head logits near zero at init
    # (CE starts at ~ln(V)).
    return {"table": maker.param(f"{name}.table", (vocab, dim),
                                 (VOCAB, EMBED), init="embed", scale=0.02)}


def embed(params, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params, x: jnp.ndarray, ctx: PhotonicCtx = EXACT_CTX
            ) -> jnp.ndarray:
    """Logits projection.  Kept in exact numerics even under photonic ctx
    (the paper quantizes conv/GEMM compute; classifier heads stay digital)."""
    del ctx
    return x @ params["table"].T


def make_mlp(maker: ParamMaker, name: str, d_model: int, d_ff: int,
             gated: bool = True) -> dict:
    p = {"up": make_dense(maker, f"{name}.up", d_model, d_ff, (EMBED, MLP)),
         "down": make_dense(maker, f"{name}.down", d_ff, d_model,
                            (MLP, EMBED))}
    if gated:
        p["gate"] = make_dense(maker, f"{name}.gate", d_model, d_ff,
                               (EMBED, MLP))
    return p


def mlp(params, x: jnp.ndarray, ctx: PhotonicCtx = EXACT_CTX,
        name: str = "mlp", act=jax.nn.silu) -> jnp.ndarray:
    up = dense(params["up"], x, ctx, f"{name}.up")
    if "gate" in params:
        gate = dense(params["gate"], x, ctx, f"{name}.gate")
        h = act(gate) * up
    else:
        h = act(up)
    return dense(params["down"], h, ctx, f"{name}.down")

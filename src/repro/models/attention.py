"""Attention: GQA (with bias / sliding-window / local-global) and MLA.

Covers every attention variant in the assigned pool:
  * qwen2: GQA with QKV bias, tiny kv_heads
  * h2o-danube3: mistral-style sliding window
  * gemma3: 5:1 local(window):global interleave
  * deepseek v2/v3: MLA — low-rank compressed KV cache; the decode path
    uses the *absorbed-weight* formulation (scores computed directly
    against the compressed c_kv cache, no per-head K materialization)
  * whisper/llava/zamba2: plain GQA / cross-attention

KV caches are explicit pytrees so serve_step can shard them:
  GQA:  {"k": (B, S, KVH, HD), "v": ..., "pos": (B, S) int32}
  MLA:  {"ckv": (B, S, R), "kr": (B, S, RD), "pos": (B, S)}
Sliding-window layers allocate min(window, S) slots and write at
``index % window`` (rolling); the ``pos`` array makes masking exact even
mid-warmup.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig
from repro.models import layers as L

NEG_INF = -2.0e38


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    rope_theta: float = 1e6
    qkv_bias: bool = False
    window: int = 0                  # 0 = full attention
    mla: Optional[MLAConfig] = None
    causal: bool = True              # False for encoder self-attention
    use_rope: bool = True
    head_pad: int = 1                # pad q heads to a multiple of this

    @property
    def padded_heads(self) -> int:
        return -(-self.num_heads // self.head_pad) * self.head_pad


def make_attention(maker: L.ParamMaker, name: str, spec: AttnSpec) -> dict:
    d, h, kvh, hd = (spec.d_model, spec.num_heads, spec.num_kv_heads,
                     spec.head_dim)
    if spec.mla is not None:
        m = spec.mla
        p = {
            "wq": L.make_dense(maker, f"{name}.wq", d,
                               h * (m.qk_nope_dim + m.qk_rope_dim),
                               (L.EMBED, L.HEADS)),
            "wkv_a": L.make_dense(maker, f"{name}.wkv_a", d,
                                  m.kv_lora_rank + m.qk_rope_dim,
                                  (L.EMBED, None)),
            "wk_b": L.make_dense(maker, f"{name}.wk_b", m.kv_lora_rank,
                                 h * m.qk_nope_dim, (None, L.HEADS)),
            "wv_b": L.make_dense(maker, f"{name}.wv_b", m.kv_lora_rank,
                                 h * m.v_head_dim, (None, L.HEADS)),
            "wo": L.make_dense(maker, f"{name}.wo", h * m.v_head_dim, d,
                               (L.HEADS, L.EMBED)),
            "kv_norm": L.make_rms_norm(maker, f"{name}.kv_norm",
                                       m.kv_lora_rank),
        }
        if m.q_lora_rank:
            p["wq_a"] = L.make_dense(maker, f"{name}.wq_a", d, m.q_lora_rank,
                                     (L.EMBED, None))
            p["wq"] = L.make_dense(maker, f"{name}.wq", m.q_lora_rank,
                                   h * (m.qk_nope_dim + m.qk_rope_dim),
                                   (None, L.HEADS))
            p["q_norm"] = L.make_rms_norm(maker, f"{name}.q_norm",
                                          m.q_lora_rank)
        return p
    hp = spec.padded_heads   # weight-level head padding (§Perf iteration 2)
    return {
        "wq": L.make_dense(maker, f"{name}.wq", d, hp * hd,
                           (L.EMBED, L.HEADS), bias=spec.qkv_bias),
        "wk": L.make_dense(maker, f"{name}.wk", d, kvh * hd,
                           (L.EMBED, L.KV_HEADS), bias=spec.qkv_bias),
        "wv": L.make_dense(maker, f"{name}.wv", d, kvh * hd,
                           (L.EMBED, L.KV_HEADS), bias=spec.qkv_bias),
        "wo": L.make_dense(maker, f"{name}.wo", hp * hd, d,
                           (L.HEADS, L.EMBED)),
    }


def init_cache(spec: AttnSpec, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> dict:
    slots = min(spec.window, max_len) if spec.window else max_len
    if spec.mla is not None:
        m = spec.mla
        return {
            "ckv": jnp.zeros((batch, slots, m.kv_lora_rank), dtype),
            "kr": jnp.zeros((batch, slots, m.qk_rope_dim), dtype),
            "pos": jnp.full((batch, slots), -1, jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, slots, spec.num_kv_heads, spec.head_dim),
                       dtype),
        "v": jnp.zeros((batch, slots, spec.num_kv_heads, spec.head_dim),
                       dtype),
        "pos": jnp.full((batch, slots), -1, jnp.int32),
    }


def _mask_bias(q_pos: jnp.ndarray, k_pos: jnp.ndarray, window: int,
               causal: bool) -> jnp.ndarray:
    """(..., Sq, Sk) additive mask from absolute positions (-1 = empty)."""
    valid = k_pos[..., None, :] >= 0
    if causal:
        valid &= k_pos[..., None, :] <= q_pos[..., :, None]
    if window:
        valid &= k_pos[..., None, :] > q_pos[..., :, None] - window
    return jnp.where(valid, 0.0, NEG_INF)


def _gqa_scores_softmax_out(q, k, v, mask_bias, real_h: int):
    """q: (B,Sq,H_pad,hd), k/v: (B,Sk,KVH,hd) -> (B,Sq,H_pad,hd).

    §Perf iteration 2 — TP-aligned attention.  GQA head counts that don't
    divide the model axis (qwen2: 14H/2KV on 16 shards) defeat GSPMD's
    sharding propagation through the group reshape, leaving the (B,H,S,S)
    f32 scores REPLICATED per device.  The fix is weight-level: wq/wo are
    padded to H_pad (multiple of the model axis), so the (B,S,H_pad*hd)
    matmul output reshapes into a cleanly sharded head axis; K/V are
    gather-expanded per padded head; dead heads (>= real_h) are hard-masked
    so semantics stay exactly ``real_h`` heads.

    Unpadded decode (Sq == 1) keeps the grouped einsum (no expansion) —
    the seq-sharded cache (flash-decode) keeps per-device scores small.
    """
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    g = max(real_h // kvh, 1)
    if sq == 1:
        # Decode: grouped einsum against the (possibly seq-sharded) cache —
        # never expand K/V across a 32k+ cache for one query token.  With a
        # padded q, slice to the real heads first (per-step tensors are
        # tiny; the cache layout is what matters).
        qr = q[:, :, :real_h, :] if h != real_h else q
        qg = qr.reshape(b, sq, kvh, g, hd)
        scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                            preferred_element_type=jnp.float32)
        scores = scores * (hd ** -0.5) + mask_bias[:, None, None]
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v).reshape(
            b, sq, real_h, hd)
        if h != real_h:
            out = jnp.pad(out, ((0, 0), (0, 0), (0, h - real_h), (0, 0)))
        return out

    kv_idx = jnp.clip(jnp.arange(h) // g, 0, kvh - 1)
    k_exp = jnp.take(k, kv_idx, axis=2)            # (B,Sk,H_pad,hd)
    v_exp = jnp.take(v, kv_idx, axis=2)
    scores = jnp.einsum("bqhd,bshd->bhqs", q, k_exp,
                        preferred_element_type=jnp.float32)
    scores = scores * (hd ** -0.5) + mask_bias[:, None]
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhqs,bshd->bqhd", probs, v_exp)
    if h != real_h:
        out = out * (jnp.arange(h) < real_h)[None, None, :, None] \
            .astype(out.dtype)
    return out


def flash_decode_gqa(q, k, v, pos, q_pos, spec: AttnSpec, dist
                     ) -> jnp.ndarray:
    """§Perf iteration 3: explicit shard_map flash-decode.

    GSPMD, left to itself, ALL-GATHERS the seq-sharded KV cache in f32 per
    layer (2 x 134 MB/step for qwen2-1.5b/decode_32k) instead of doing a
    distributed softmax.  This shard_map makes the flash-decode pattern
    explicit: each model shard attends over its cache slots, and only the
    per-head (max, sum, weighted-V) stats cross links — O(B*H*hd) psum
    instead of O(B*S*KVH*hd) gather.

    q: (B,1,H,hd) [real heads only]; k/v: (B,S,KVH,hd) with the slots dim
    sharded over ``seq_axes``; pos: (B,S); q_pos: (B,1).  When the batch
    divides the data axes, batch is data-sharded and slots are model-
    sharded; for B=1 long-context cells, slots shard over ALL axes
    (data+model) and the combine psums over all of them.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    b, _, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    batch_axes, seq_axes = decode_axes(dist, b, k.shape[1])
    dspec = P(batch_axes) if batch_axes else P(None)
    seq_spec = tuple(seq_axes)
    scale = hd ** -0.5

    def body(q_l, k_l, v_l, pos_l, qpos_l):
        qg = q_l.reshape(q_l.shape[0], 1, kvh, g, hd)
        scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_l,
                            preferred_element_type=jnp.float32) * scale
        bias = _mask_bias(qpos_l, pos_l, spec.window, spec.causal)
        scores = scores + bias[:, None, None]
        m_loc = jnp.max(scores, axis=-1)                    # (b,kvh,g,1)
        p = jnp.exp(scores - m_loc[..., None])
        l_loc = jnp.sum(p, axis=-1)
        # p in the cache dtype: avoids materializing an f32 copy of the
        # whole V cache (the dot still accumulates in f32).
        o_loc = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(v_l.dtype), v_l,
                           preferred_element_type=jnp.float32)
        m_g = jax.lax.pmax(m_loc, seq_spec)
        c = jnp.exp(m_loc - m_g)
        l_g = jax.lax.psum(l_loc * c, seq_spec)
        o_g = jax.lax.psum(o_loc * c[..., None], seq_spec)
        out = o_g / jnp.maximum(l_g, 1e-30)[..., None]
        return out.reshape(q_l.shape[0], 1, h, hd).astype(q_l.dtype)

    return shard_map(
        body, mesh=dist.mesh,
        in_specs=(P(*dspec, None, None, None),
                  P(*dspec, seq_spec, None, None),
                  P(*dspec, seq_spec, None, None),
                  P(*dspec, seq_spec),
                  P(*dspec, None)),
        out_specs=P(*dspec, None, None, None),
        check_rep=False,
    )(q, k, v, pos, q_pos)


def decode_axes(dist, batch: int, slots: int):
    """(batch_axes, seq_axes) for the flash-decode layout, or (None, None)
    if the cell can't use it (indivisible slot count)."""
    if dist is None or getattr(dist, "mesh", None) is None:
        return None, None
    dsize = 1
    for a in dist.data_axes:
        dsize *= dist.mesh.shape[a]
    if batch % dsize == 0:
        batch_axes = tuple(dist.data_axes)
        seq_axes = (dist.model_axis,)
    else:
        batch_axes = ()
        seq_axes = tuple(dist.data_axes) + (dist.model_axis,)
    shards = 1
    for a in seq_axes:
        shards *= dist.mesh.shape[a]
    if slots % shards != 0:
        return None, None
    return batch_axes, seq_axes


def attention(params: dict, x: jnp.ndarray, positions: jnp.ndarray,
              spec: AttnSpec, ctx: L.PhotonicCtx = L.EXACT_CTX,
              name: str = "attn",
              cache: Optional[dict] = None,
              cache_index: Optional[jnp.ndarray] = None,
              kv_source: Optional[jnp.ndarray] = None,
              kv_positions: Optional[jnp.ndarray] = None,
              dist=None, attn_impl: str = "xla",
              ) -> Tuple[jnp.ndarray, Optional[dict]]:
    """Self- or cross-attention.

    x: (B, S, D); positions: (B, S) absolute positions of x.
    cache + cache_index=None  -> prefill: fill cache slots [0, S).
    cache + cache_index=i     -> decode: write at slot i % slots, S must be 1.
    kv_source                 -> cross-attention (no cache, no rope).
    attn_impl                 -> 'xla' (default) or 'pallas' (flash kernel
                                 on the cache-less self-attention path).
    Returns (out, updated_cache_or_None).
    """
    if spec.mla is not None:
        return _mla_attention(params, x, positions, spec, ctx, name, cache,
                              cache_index)
    b, s, _ = x.shape
    h, kvh, hd = spec.padded_heads, spec.num_kv_heads, spec.head_dim
    q = L.dense(params["wq"], x, ctx, f"{name}.wq").reshape(b, s, h, hd)
    kv_in = kv_source if kv_source is not None else x
    sk = kv_in.shape[1]
    k = L.dense(params["wk"], kv_in, ctx, f"{name}.wk").reshape(b, sk, kvh, hd)
    v = L.dense(params["wv"], kv_in, ctx, f"{name}.wv").reshape(b, sk, kvh, hd)

    if spec.use_rope:
        q = L.apply_rope(q, positions, spec.rope_theta)
        if kv_source is None:
            k = L.apply_rope(k, positions, spec.rope_theta)

    new_cache = None
    if kv_source is not None:
        kpos = kv_positions if kv_positions is not None else \
            jnp.broadcast_to(jnp.arange(sk, dtype=jnp.int32)[None], (b, sk))
        bias = _mask_bias(positions, kpos, 0, causal=False)
    elif cache is None:
        bias = _mask_bias(positions, positions, spec.window, spec.causal)
    else:
        slots = cache["k"].shape[1]
        if cache_index is None:                      # prefill into cache
            # Windowed caches keep only the last ``slots`` positions, placed
            # at slot = position % slots so later rolling decode writes stay
            # consistent with the prefill layout.
            kk = k[:, -slots:] if s > slots else k
            vv = v[:, -slots:] if s > slots else v
            pp = positions[:, -slots:] if s > slots else positions
            slot_idx = pp[0].astype(jnp.int32) % slots
            new_cache = {
                "k": cache["k"].at[:, slot_idx].set(
                    kk.astype(cache["k"].dtype)),
                "v": cache["v"].at[:, slot_idx].set(
                    vv.astype(cache["v"].dtype)),
                "pos": cache["pos"].at[:, slot_idx].set(
                    pp.astype(jnp.int32)),
            }
            bias = _mask_bias(positions, positions, spec.window, spec.causal)
        else:                                        # single-token decode
            assert s == 1
            slot = (cache_index % slots).astype(jnp.int32)
            new_cache = {
                "k": jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], k.astype(cache["k"].dtype), slot, axis=1),
                "v": jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], v.astype(cache["v"].dtype), slot, axis=1),
                "pos": jax.lax.dynamic_update_slice_in_dim(
                    cache["pos"], positions.astype(jnp.int32), slot, axis=1),
            }
            flash_axes = decode_axes(dist, b, new_cache["k"].shape[1])
            if flash_axes[1] is not None:
                qr = q[:, :, :spec.num_heads, :]
                fo = flash_decode_gqa(qr, new_cache["k"], new_cache["v"],
                                      new_cache["pos"], positions, spec,
                                      dist)
                if h != spec.num_heads:
                    fo = jnp.pad(fo, ((0, 0), (0, 0),
                                      (0, h - spec.num_heads), (0, 0)))
                fo = L.dense(params["wo"], fo.reshape(b, s, h * hd), ctx,
                             f"{name}.wo")
                return fo, new_cache
            k, v = new_cache["k"], new_cache["v"]
            bias = _mask_bias(positions, new_cache["pos"], spec.window,
                              spec.causal)
    if attn_impl == "pallas" and s > 1 and kv_source is None and \
            cache is None:
        # Pallas flash-attention for the train/prefill hot path (no cache,
        # self-attention): heads fold into the batch dim per the kernel's
        # layout contract; K/V expand per (padded) head first.
        from repro.kernels.flash_attention import flash_attention_fwd
        kv_idx = jnp.clip(jnp.arange(h) // max(spec.num_heads // kvh, 1),
                          0, kvh - 1)
        k_e = jnp.take(k, kv_idx, axis=2)
        v_e = jnp.take(v, kv_idx, axis=2)
        fold = lambda t: t.transpose(0, 2, 1, 3).reshape(b * h, s, hd)  # noqa
        o = flash_attention_fwd(fold(q), fold(k_e), fold(v_e),
                                causal=spec.causal, window=spec.window,
                                interpret=jax.default_backend() == "cpu")
        out = o.reshape(b, h, s, hd).transpose(0, 2, 1, 3)
        if h != spec.num_heads:
            out = out * (jnp.arange(h) < spec.num_heads)[None, None, :, None] \
                .astype(out.dtype)
    else:
        out = _gqa_scores_softmax_out(q, k, v, bias, spec.num_heads)
    out = L.dense(params["wo"], out.reshape(b, s, h * hd), ctx,
                  f"{name}.wo")
    return out, new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek v2/v3)
# ---------------------------------------------------------------------------
def _mla_qkr(params, x, positions, spec, ctx, name):
    b, s, _ = x.shape
    m, h = spec.mla, spec.num_heads
    if "wq_a" in params:
        qa = L.dense(params["wq_a"], x, ctx, f"{name}.wq_a")
        qa = L.rms_norm(params["q_norm"], qa)
        q = L.dense(params["wq"], qa, ctx, f"{name}.wq")
    else:
        q = L.dense(params["wq"], x, ctx, f"{name}.wq")
    q = q.reshape(b, s, h, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_dim], axis=-1)
    q_rope = L.apply_rope(q_rope, positions, spec.rope_theta)
    kv_a = L.dense(params["wkv_a"], x, ctx, f"{name}.wkv_a")
    ckv, k_rope = jnp.split(kv_a, [m.kv_lora_rank], axis=-1)
    ckv = L.rms_norm(params["kv_norm"], ckv)
    k_rope = L.apply_rope(k_rope[:, :, None, :], positions,
                          spec.rope_theta)[:, :, 0, :]
    return q_nope, q_rope, ckv, k_rope


def _mla_attention(params, x, positions, spec, ctx, name, cache,
                   cache_index):
    b, s, _ = x.shape
    m, h = spec.mla, spec.num_heads
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    q_nope, q_rope, ckv, k_rope = _mla_qkr(params, x, positions, spec, ctx,
                                           name)
    new_cache = None
    if cache is not None:
        if cache_index is None:                      # prefill into cache
            new_cache = {
                "ckv": jax.lax.dynamic_update_slice(
                    cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, 0, 0)),
                "kr": jax.lax.dynamic_update_slice(
                    cache["kr"], k_rope.astype(cache["kr"].dtype), (0, 0, 0)),
                "pos": jax.lax.dynamic_update_slice(
                    cache["pos"], positions.astype(jnp.int32), (0, 0)),
            }
            kv_ckv, kv_kr, kpos = ckv, k_rope, positions
        else:                                        # absorbed decode
            assert s == 1
            slots = cache["ckv"].shape[1]
            slot = (cache_index % slots).astype(jnp.int32)
            new_cache = {
                "ckv": jax.lax.dynamic_update_slice_in_dim(
                    cache["ckv"], ckv.astype(cache["ckv"].dtype), slot, 1),
                "kr": jax.lax.dynamic_update_slice_in_dim(
                    cache["kr"], k_rope.astype(cache["kr"].dtype), slot, 1),
                "pos": jax.lax.dynamic_update_slice_in_dim(
                    cache["pos"], positions.astype(jnp.int32), slot, 1),
            }
            kv_ckv, kv_kr, kpos = (new_cache["ckv"], new_cache["kr"],
                                   new_cache["pos"])
    else:
        kv_ckv, kv_kr, kpos = ckv, k_rope, positions

    bias = _mask_bias(positions, kpos, spec.window, spec.causal)

    if cache_index is not None:
        # Absorbed-weight decode: score against c_kv directly.
        wk_b = params["wk_b"]["w"].reshape(m.kv_lora_rank, h, m.qk_nope_dim)
        q_abs = jnp.einsum("bqhd,rhd->bqhr", q_nope, wk_b)     # (B,1,H,R)
        scores = (jnp.einsum("bqhr,bsr->bhqs", q_abs, kv_ckv,
                             preferred_element_type=jnp.float32) +
                  jnp.einsum("bqhd,bsd->bhqs", q_rope, kv_kr,
                             preferred_element_type=jnp.float32))
        probs = jax.nn.softmax(scores * scale + bias[:, None], -1)
        ctx_r = jnp.einsum("bhqs,bsr->bqhr", probs.astype(kv_ckv.dtype),
                           kv_ckv)                              # (B,1,H,R)
        wv_b = params["wv_b"]["w"].reshape(m.kv_lora_rank, h, m.v_head_dim)
        out = jnp.einsum("bqhr,rhv->bqhv", ctx_r, wv_b)
    else:
        # Naive (training/prefill) path: materialize per-head K/V.
        sk = kv_ckv.shape[1]
        k_nope = L.dense(params["wk_b"], kv_ckv, ctx, f"{name}.wk_b") \
            .reshape(b, sk, h, m.qk_nope_dim)
        v = L.dense(params["wv_b"], kv_ckv, ctx, f"{name}.wv_b") \
            .reshape(b, sk, h, m.v_head_dim)
        scores = (jnp.einsum("bqhd,bshd->bhqs", q_nope, k_nope,
                             preferred_element_type=jnp.float32) +
                  jnp.einsum("bqhd,bsd->bhqs", q_rope, kv_kr,
                             preferred_element_type=jnp.float32))
        probs = jax.nn.softmax(scores * scale + bias[:, None], -1)
        out = jnp.einsum("bhqs,bshv->bqhv", probs.astype(v.dtype), v)
    out = L.dense(params["wo"], out.reshape(b, s, h * m.v_head_dim), ctx,
                  f"{name}.wo")
    return out, new_cache

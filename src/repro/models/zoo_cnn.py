"""Executable paper model zoo: reduced-scale runnable variants of the
four evaluation CNNs (paper §6.2 — GoogleNet, ResNet50, MobileNetV2,
ShuffleNet-V2) on the lowering IR (models.lowering).

Each variant keeps its network's *structural signature* — the thing the
full-size analytic tables in models.cnn model — at a scale the host
simulation executes in seconds:

  * resnet_mini      bottleneck residual blocks (1x1 -> 3x3 -> 1x1 with
                     projection/identity shortcuts, one stride-2 stage)
  * mobilenet_mini   inverted residuals: 1x1 expand -> depthwise 3x3
                     (stride 1 and 2) -> linear 1x1 project, residual
                     only at stride 1 with matching channels
  * shufflenet_mini  stride-2 two-branch unit + split/concat basic unit
                     with channel shuffle
  * googlenet_mini   inception branch+concat (1x1 / 3x3 / 5x5 / pooled
                     projection)
  * small_cnn        the original runnable toy net, as a graph

Every ``ZooModel`` carries both views of the network from ONE graph:
``gemms()`` (what the scheduler/executor consume) and ``analytic()``
(the same layers written with the paper-table helpers ``_conv``/``_dw``
that generate models.cnn.CNN_ZOO and feed benchmarks/fig11_fps.py).
tests/test_zoo_conformance.py pins the two against each other layer by
layer, so the runnable lowering cannot drift from the analytic
accounting.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Tuple

import jax

from repro.models import lowering as lw
from repro.models.cnn import LayerGemm, _conv, _dw, small_cnn_graph
from repro.models.lowering import (OpGraph, concat, conv, dwconv, fc,
                                   global_avg, input_node, pool, residual,
                                   shuffle, slice_ch)


@dataclasses.dataclass(frozen=True)
class ZooModel:
    """One runnable zoo network: graph + input geometry + analytic view."""
    name: str
    graph: OpGraph
    in_hw: Tuple[int, int]
    num_classes: int
    _analytic: Callable[[], List[LayerGemm]]

    @property
    def in_ch(self) -> int:
        return self.graph.input.cout

    def init_params(self, key: jax.Array) -> Dict[str, jax.Array]:
        return lw.init_params(self.graph, key, self.in_hw)

    def gemms(self, params: dict = None) -> List[LayerGemm]:
        """The executor/scheduler GEMM table, straight off the graph."""
        return lw.graph_gemms(self.graph, self.in_hw, params=params)

    def analytic(self) -> List[LayerGemm]:
        """The same network written with the paper-table formulas
        (models.cnn._conv/_dw) — the fig11-style accounting."""
        return self._analytic()


def _resnet_mini() -> ZooModel:
    """Three ResNet50-style bottleneck blocks at 32x32: projection
    shortcut, stride-2 downsample block, identity block."""
    g = OpGraph((
        input_node(3),
        conv("stem", "input", 16),
        # block 1: projection shortcut (16 -> 32 channels), stride 1
        conv("b1_1x1a", "stem", 8, kk=1),
        conv("b1_3x3", "b1_1x1a", 8),
        conv("b1_1x1b", "b1_3x3", 32, kk=1, relu=False),
        conv("b1_ds", "stem", 32, kk=1, relu=False),
        residual("b1_add", "b1_1x1b", "b1_ds"),
        # block 2: stride-2 downsample (32x32 -> 16x16, 32 -> 64 ch)
        conv("b2_1x1a", "b1_add", 16, kk=1),
        conv("b2_3x3", "b2_1x1a", 16, stride=2),
        conv("b2_1x1b", "b2_3x3", 64, kk=1, relu=False),
        conv("b2_ds", "b1_add", 64, kk=1, stride=2, relu=False),
        residual("b2_add", "b2_1x1b", "b2_ds"),
        # block 3: identity shortcut
        conv("b3_1x1a", "b2_add", 16, kk=1),
        conv("b3_3x3", "b3_1x1a", 16),
        conv("b3_1x1b", "b3_3x3", 64, kk=1, relu=False),
        residual("b3_add", "b3_1x1b", "b2_add"),
        global_avg("gap", "b3_add"),
        fc("fc", "gap", 10),
    ))

    def analytic() -> List[LayerGemm]:
        return [
            _conv("stem", 32, 3, 3, 16),
            _conv("b1_1x1a", 32, 16, 1, 8),
            _conv("b1_3x3", 32, 8, 3, 8),
            _conv("b1_1x1b", 32, 8, 1, 32),
            _conv("b1_ds", 32, 16, 1, 32),
            _conv("b2_1x1a", 32, 32, 1, 16),
            _conv("b2_3x3", 16, 16, 3, 16),
            _conv("b2_1x1b", 16, 16, 1, 64),
            _conv("b2_ds", 16, 32, 1, 64),
            _conv("b3_1x1a", 16, 64, 1, 16),
            _conv("b3_3x3", 16, 16, 3, 16),
            _conv("b3_1x1b", 16, 16, 1, 64),
            LayerGemm("fc", 1, 64, 10),
        ]

    return ZooModel("resnet_mini", g, (32, 32), 10, analytic)


def _mobilenet_mini() -> ZooModel:
    """MobileNetV2-style inverted residuals at 32x32: t=1 first block,
    t=6 stride-2 block, t=6 residual block (linear bottlenecks — no
    activation after the projection, residual add without ReLU)."""
    g = OpGraph((
        input_node(3),
        conv("stem", "input", 8, stride=2),
        # t=1 block: depthwise + linear project (8 -> 16 ch)
        dwconv("ir1_dw", "stem", relu=True),
        conv("ir1_pw", "ir1_dw", 16, kk=1, relu=False),
        # t=6 stride-2 block (16 -> 24 ch, 16x16 -> 8x8)
        conv("ir2_ex", "ir1_pw", 96, kk=1),
        dwconv("ir2_dw", "ir2_ex", stride=2, relu=True),
        conv("ir2_pw", "ir2_dw", 24, kk=1, relu=False),
        # t=6 residual block (24 -> 24 ch, stride 1: shortcut applies)
        conv("ir3_ex", "ir2_pw", 144, kk=1),
        dwconv("ir3_dw", "ir3_ex", relu=True),
        conv("ir3_pw", "ir3_dw", 24, kk=1, relu=False),
        residual("ir3_add", "ir3_pw", "ir2_pw", relu=False),
        conv("head", "ir3_add", 64, kk=1),
        global_avg("gap", "head"),
        fc("fc", "gap", 10),
    ))

    def analytic() -> List[LayerGemm]:
        return [
            _conv("stem", 16, 3, 3, 8),
            _dw("ir1_dw", 16, 8),
            _conv("ir1_pw", 16, 8, 1, 16),
            _conv("ir2_ex", 16, 16, 1, 96),
            _dw("ir2_dw", 8, 96),
            _conv("ir2_pw", 8, 96, 1, 24),
            _conv("ir3_ex", 8, 24, 1, 144),
            _dw("ir3_dw", 8, 144),
            _conv("ir3_pw", 8, 144, 1, 24),
            _conv("head", 8, 24, 1, 64),
            LayerGemm("fc", 1, 64, 10),
        ]

    return ZooModel("mobilenet_mini", g, (32, 32), 10, analytic)


def _shufflenet_mini() -> ZooModel:
    """ShuffleNet-V2 units at 32x32: the stride-2 two-branch unit
    (both branches concat to 2x channels) and the basic unit (channel
    split, one branch transformed, concat) — each followed by the
    channel shuffle."""
    g = OpGraph((
        input_node(3),
        conv("stem", "input", 16),
        # stride-2 unit: branch 1 = dw/s2 + pw, branch 2 = pw + dw/s2 + pw
        dwconv("d1_b1dw", "stem", stride=2),
        conv("d1_b1pw", "d1_b1dw", 16, kk=1),
        conv("d1_b2pw1", "stem", 16, kk=1),
        dwconv("d1_b2dw", "d1_b2pw1", stride=2),
        conv("d1_b2pw2", "d1_b2dw", 16, kk=1),
        concat("d1_cat", "d1_b1pw", "d1_b2pw2"),
        shuffle("d1_shuf", "d1_cat", groups=2),
        # basic unit: split 32 -> 16 + 16, transform one branch
        slice_ch("u1_keep", "d1_shuf", 0, 16),
        slice_ch("u1_in", "d1_shuf", 16, 32),
        conv("u1_pw1", "u1_in", 16, kk=1),
        dwconv("u1_dw", "u1_pw1"),
        conv("u1_pw2", "u1_dw", 16, kk=1),
        concat("u1_cat", "u1_keep", "u1_pw2"),
        shuffle("u1_shuf", "u1_cat", groups=2),
        global_avg("gap", "u1_shuf"),
        fc("fc", "gap", 10),
    ))

    def analytic() -> List[LayerGemm]:
        return [
            _conv("stem", 32, 3, 3, 16),
            _dw("d1_b1dw", 16, 16),
            _conv("d1_b1pw", 16, 16, 1, 16),
            _conv("d1_b2pw1", 32, 16, 1, 16),
            _dw("d1_b2dw", 16, 16),
            _conv("d1_b2pw2", 16, 16, 1, 16),
            _conv("u1_pw1", 16, 16, 1, 16),
            _dw("u1_dw", 16, 16),
            _conv("u1_pw2", 16, 16, 1, 16),
            LayerGemm("fc", 1, 32, 10),
        ]

    return ZooModel("shufflenet_mini", g, (32, 32), 10, analytic)


def _googlenet_mini() -> ZooModel:
    """A GoogleNet inception module at 32x32: four branches (1x1,
    1x1->3x3, 1x1->5x5, 3x3-maxpool->1x1) concatenated."""
    g = OpGraph((
        input_node(3),
        conv("stem", "input", 16),
        pool("stem.pool", "stem"),
        conv("i_1x1", "stem.pool", 8, kk=1),
        conv("i_3r", "stem.pool", 8, kk=1),
        conv("i_3", "i_3r", 16),
        conv("i_5r", "stem.pool", 4, kk=1),
        conv("i_5", "i_5r", 8, kk=5),
        pool("i_pool", "stem.pool", size=3, stride=1, padding="same"),
        conv("i_pp", "i_pool", 8, kk=1),
        concat("i_cat", "i_1x1", "i_3", "i_5", "i_pp"),
        global_avg("gap", "i_cat"),
        fc("fc", "gap", 10),
    ))

    def analytic() -> List[LayerGemm]:
        return [
            _conv("stem", 32, 3, 3, 16),
            _conv("i_1x1", 16, 16, 1, 8),
            _conv("i_3r", 16, 16, 1, 8),
            _conv("i_3", 16, 8, 3, 16),
            _conv("i_5r", 16, 16, 1, 4),
            _conv("i_5", 16, 4, 5, 8),
            _conv("i_pp", 16, 16, 1, 8),
            LayerGemm("fc", 1, 40, 10),
        ]

    return ZooModel("googlenet_mini", g, (32, 32), 10, analytic)


def _small_cnn() -> ZooModel:
    g = small_cnn_graph()

    def analytic() -> List[LayerGemm]:
        return [
            _conv("conv1", 16, 3, 3, 16),
            _conv("conv2", 8, 16, 3, 32),
            _conv("conv3", 4, 32, 3, 32),
            LayerGemm("fc", 1, 512, 10),
        ]

    return ZooModel("small_cnn", g, (16, 16), 10, analytic)


ZOO: Dict[str, ZooModel] = {m.name: m for m in (
    _resnet_mini(), _mobilenet_mini(), _shufflenet_mini(),
    _googlenet_mini(), _small_cnn())}

#: The four paper evaluation networks (Fig. 11 / Table 4) only.
PAPER_ZOO: Dict[str, ZooModel] = {
    k: v for k, v in ZOO.items() if k != "small_cnn"}

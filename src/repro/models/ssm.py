"""Mamba2 (SSD) block — arXiv:2405.21060, TPU-adapted via kernels/ssd_scan.

Block: in_proj -> [z | xBC | dt]; causal depthwise conv over xBC; SSD scan
(chunked — Pallas kernel on the serving path, differentiable jnp on the
training path); gated RMSNorm; out_proj.

Decode state: {"conv": (B, W-1, C_xbc), "ssm": (B, H, P, S)} — O(1) per
token, which is what makes the SSM archs the long_500k cells.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.kernels import ops as kops
from repro.models import layers as L


def dims(d_model: int, s: SSMConfig):
    d_inner = s.expand * d_model
    n_heads = d_inner // s.head_dim
    d_xbc = d_inner + 2 * s.n_groups * s.state_dim
    return d_inner, n_heads, d_xbc


def make_mamba(maker: L.ParamMaker, name: str, d_model: int,
               s: SSMConfig) -> dict:
    d_inner, n_heads, d_xbc = dims(d_model, s)
    d_in_proj = 2 * d_inner + 2 * s.n_groups * s.state_dim + n_heads
    return {
        "in_proj": L.make_dense(maker, f"{name}.in_proj", d_model, d_in_proj,
                                (L.EMBED, L.SSM_INNER)),
        "conv_w": maker.param(f"{name}.conv_w", (s.conv_width, d_xbc),
                              (None, L.SSM_INNER), scale=s.conv_width ** -0.5),
        "conv_b": maker.param(f"{name}.conv_b", (d_xbc,), (L.SSM_INNER,),
                              init="zeros"),
        "dt_bias": maker.param(f"{name}.dt_bias", (n_heads,), (None,),
                               init="zeros"),
        "a_log": maker.param(f"{name}.a_log", (n_heads,), (None,),
                             init="zeros"),
        "d_skip": maker.param(f"{name}.d_skip", (n_heads,), (None,),
                              init="ones"),
        "norm": L.make_rms_norm(maker, f"{name}.norm", d_inner),
        "out_proj": L.make_dense(maker, f"{name}.out_proj", d_inner, d_model,
                                 (L.SSM_INNER, L.EMBED)),
    }


def init_state(d_model: int, s: SSMConfig, batch: int,
               dtype=jnp.float32) -> dict:
    d_inner, n_heads, d_xbc = dims(d_model, s)
    return {
        "conv": jnp.zeros((batch, s.conv_width - 1, d_xbc), dtype),
        "ssm": jnp.zeros((batch, n_heads, s.head_dim, s.state_dim), dtype),
    }


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 history: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Depthwise causal conv, width W.  history: (B, W-1, C) carried state."""
    bsz, l, c = xbc.shape
    width = w.shape[0]
    if history is None:
        history = jnp.zeros((bsz, width - 1, c), xbc.dtype)
    xp = jnp.concatenate([history.astype(xbc.dtype), xbc], axis=1)
    out = sum(xp[:, i:i + l, :] * w[i] for i in range(width))
    return jax.nn.silu(out + b)


def _split(params, x, d_model, s: SSMConfig, ctx, name):
    d_inner, n_heads, d_xbc = dims(d_model, s)
    proj = L.dense(params["in_proj"], x, ctx, f"{name}.in_proj")
    z, xbc, dt = jnp.split(proj, [d_inner, d_inner + d_xbc], axis=-1)
    return z, xbc, dt, d_inner, n_heads


def mamba_block(params: dict, x: jnp.ndarray, d_model: int, s: SSMConfig,
                ctx: L.PhotonicCtx = L.EXACT_CTX, name: str = "mamba",
                state: Optional[dict] = None, return_state: bool = False,
                impl: str = "jax") -> Tuple[jnp.ndarray, Optional[dict]]:
    """Full-sequence Mamba2 block.  x: (B, L, D)."""
    bsz, l, _ = x.shape
    z, xbc_raw, dt, d_inner, n_heads = _split(params, x, d_model, s, ctx,
                                              name)
    conv_hist = None if state is None else state["conv"]
    xbc = _causal_conv(xbc_raw, params["conv_w"], params["conv_b"], conv_hist)
    xs, b, c = jnp.split(
        xbc, [d_inner, d_inner + s.n_groups * s.state_dim], axis=-1)
    p, g = s.head_dim, s.n_groups
    heads_per_group = n_heads // g

    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         params["dt_bias"].astype(jnp.float32))  # (B,L,H)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))            # (H,)

    # flatten to (B*H, L, ...) for the kernel
    xh = xs.reshape(bsz, l, n_heads, p).transpose(0, 2, 1, 3) \
        .reshape(bsz * n_heads, l, p)
    dth = dt.transpose(0, 2, 1).reshape(bsz * n_heads, l)
    ah = jnp.tile(a, bsz)
    bg = b.reshape(bsz, l, g, s.state_dim)
    cg = c.reshape(bsz, l, g, s.state_dim)
    bh = jnp.repeat(bg, heads_per_group, axis=2).transpose(0, 2, 1, 3) \
        .reshape(bsz * n_heads, l, s.state_dim)
    ch = jnp.repeat(cg, heads_per_group, axis=2).transpose(0, 2, 1, 3) \
        .reshape(bsz * n_heads, l, s.state_dim)

    y, final = kops.ssd_scan(xh.astype(jnp.float32), dth, ah,
                             bh.astype(jnp.float32), ch.astype(jnp.float32),
                             chunk=s.chunk, impl=impl)
    y = y.reshape(bsz, n_heads, l, p).transpose(0, 2, 1, 3)
    y = y + xh.reshape(bsz, n_heads, l, p).transpose(0, 2, 1, 3) * \
        params["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(bsz, l, d_inner).astype(x.dtype)

    y = L.rms_norm(params["norm"], y * jax.nn.silu(z))
    out = L.dense(params["out_proj"], y, ctx, f"{name}.out_proj")

    new_state = None
    if return_state:
        hist = (jnp.zeros((bsz, s.conv_width - 1, xbc_raw.shape[-1]),
                          xbc_raw.dtype) if state is None
                else state["conv"].astype(xbc_raw.dtype))
        # conv history = last W-1 *raw* conv inputs
        new_state = {
            "conv": jnp.concatenate([hist, xbc_raw], axis=1)
            [:, -(s.conv_width - 1):, :].astype(jnp.float32),
            "ssm": final.reshape(bsz, n_heads, p, s.state_dim),
        }
    return out, new_state


def mamba_decode_step(params: dict, x: jnp.ndarray, d_model: int,
                      s: SSMConfig, state: dict,
                      ctx: L.PhotonicCtx = L.EXACT_CTX,
                      name: str = "mamba") -> Tuple[jnp.ndarray, dict]:
    """Single-token decode.  x: (B, 1, D); state from init_state/prefill."""
    bsz = x.shape[0]
    z, xbc, dt, d_inner, n_heads = _split(params, x, d_model, s, ctx, name)
    width = s.conv_width
    # rolling conv state
    hist = state["conv"].astype(xbc.dtype)                 # (B, W-1, C)
    window = jnp.concatenate([hist, xbc], axis=1)          # (B, W, C)
    conv_out = jnp.einsum("bwc,wc->bc", window, params["conv_w"]) + \
        params["conv_b"]
    xbc_t = jax.nn.silu(conv_out)                          # (B, C)
    new_conv = window[:, 1:, :].astype(jnp.float32)

    xs, b, c = jnp.split(
        xbc_t, [d_inner, d_inner + s.n_groups * s.state_dim], axis=-1)
    p, g = s.head_dim, s.n_groups
    hpg = n_heads // g
    dt_t = jax.nn.softplus(dt[:, 0].astype(jnp.float32) +
                           params["dt_bias"].astype(jnp.float32))  # (B,H)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))

    xh = xs.reshape(bsz * n_heads, p).astype(jnp.float32)
    bh = jnp.repeat(b.reshape(bsz, g, s.state_dim), hpg, axis=1) \
        .reshape(bsz * n_heads, s.state_dim).astype(jnp.float32)
    ch = jnp.repeat(c.reshape(bsz, g, s.state_dim), hpg, axis=1) \
        .reshape(bsz * n_heads, s.state_dim).astype(jnp.float32)
    st = state["ssm"].reshape(bsz * n_heads, p, s.state_dim)
    y, new_st = kops.ssd_decode_step(st, xh, dt_t.reshape(-1),
                                     jnp.tile(a, bsz), bh, ch)
    y = y + xh * jnp.tile(params["d_skip"].astype(jnp.float32), bsz)[:, None]
    y = y.reshape(bsz, 1, d_inner).astype(x.dtype)
    y = L.rms_norm(params["norm"], y * jax.nn.silu(z))
    out = L.dense(params["out_proj"], y, ctx, f"{name}.out_proj")
    return out, {"conv": new_conv,
                 "ssm": new_st.reshape(bsz, n_heads, p, s.state_dim)}

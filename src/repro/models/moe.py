"""Mixture-of-Experts FFN with shard_map expert parallelism (EP).

DeepSeek-style: ``num_shared_experts`` always-on shared experts plus
``num_experts`` routed experts with top-k routing.

Distribution design (DESIGN.md §5): activations are batch-sharded over the
data axes and *replicated* over the model axis, while expert weights are
sharded over the model axis (EP).  Each model shard therefore selects the
token->expert assignments that target ITS experts, computes them locally
under a fixed capacity, and the shards' partial outputs are psum'd.  No
(T, E, C) dispatch tensor is ever materialized — at DeepSeek-V3 scale that
tensor would be ~5e13 elements, which is why the GShard einsum formulation
is replaced by gather/scatter + a batched per-expert einsum.

Everything is fully differentiable (sorts become gathers/scatters in the
VJP), so photonic-aware QAT works through MoE layers too.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import MoEConfig
from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class DistCtx:
    """How a model apply() is distributed (None mesh = single process)."""
    mesh: Optional[object] = None          # jax.sharding.Mesh
    data_axes: tuple = ("data",)           # ("pod","data") when multi-pod
    model_axis: str = "model"

    @property
    def model_shards(self) -> int:
        if self.mesh is None:
            return 1
        return self.mesh.shape[self.model_axis]


LOCAL = DistCtx()


def make_moe(maker: L.ParamMaker, name: str, d_model: int,
             cfg: MoEConfig) -> dict:
    e, f = cfg.num_experts, cfg.d_ff_expert
    p = {
        "router": maker.param(f"{name}.router", (d_model, e),
                              (L.EMBED, L.EXPERT), scale=d_model ** -0.5),
        "gate": maker.param(f"{name}.gate", (e, d_model, f),
                            (L.EXPERT, L.EMBED, L.MLP)),
        "up": maker.param(f"{name}.up", (e, d_model, f),
                          (L.EXPERT, L.EMBED, L.MLP)),
        "down": maker.param(f"{name}.down", (e, f, d_model),
                            (L.EXPERT, L.MLP, L.EMBED)),
    }
    if cfg.num_shared_experts:
        p["shared"] = L.make_mlp(maker, f"{name}.shared", d_model,
                                 cfg.num_shared_experts * f)
    return p


def _round8(x: int) -> int:
    return max(8, ((x + 7) // 8) * 8)


def _routed_local(router_w, gate_w, up_w, down_w, x, cfg: MoEConfig,
                  n_shards: int, my_shard) -> jnp.ndarray:
    """Routed-expert compute for ONE model shard (local expert slice).

    x: (B, S, D) — this shard's replica of the activations.
    gate/up/down: (E_loc, ...) local expert slice.  Returns this shard's
    partial output (zeros for tokens routed elsewhere).
    """
    b, s, d = x.shape
    t = b * s
    k = cfg.experts_per_token
    e_total = cfg.num_experts
    e_loc = e_total // n_shards
    xf = x.reshape(t, d)

    logits = (xf @ router_w.astype(jnp.float32).astype(xf.dtype)) \
        .astype(jnp.float32)                                  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                    # (T, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # --- select the (token, expert) slots owned by this shard ---
    eid = top_e.reshape(t * k)
    wgt = top_p.reshape(t * k)
    owner = eid // e_loc
    sel = owner == my_shard
    cap = _round8(int(t * k * cfg.capacity_factor) // n_shards)
    cap = min(cap, t * k)
    order = jnp.argsort(~sel, stable=True)                    # selected first
    slots = order[:cap]
    valid = sel[slots]
    token_ids = slots // k
    e_local = jnp.where(valid, eid[slots] - my_shard * e_loc, 0)
    w_slots = jnp.where(valid, wgt[slots], 0.0)

    # --- group by local expert under a per-expert capacity ---
    cap_e = _round8(int(cap * cfg.capacity_factor) // max(e_loc, 1))
    grp = jax.nn.one_hot(e_local, e_loc, dtype=jnp.int32) * \
        valid[:, None].astype(jnp.int32)                      # (cap, E_loc)
    pos = jnp.take_along_axis(jnp.cumsum(grp, axis=0), e_local[:, None],
                              axis=1)[:, 0] - 1               # (cap,)
    keep = valid & (pos >= 0) & (pos < cap_e)
    pos = jnp.clip(pos, 0, cap_e - 1)

    xg = xf[token_ids] * keep[:, None].astype(xf.dtype)       # (cap, D)
    disp = jnp.zeros((e_loc, cap_e, d), xf.dtype)
    disp = disp.at[e_local, pos].add(xg)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", disp, gate_w)) * \
        jnp.einsum("ecd,edf->ecf", disp, up_w)
    out_e = jnp.einsum("ecf,efd->ecd", h, down_w)             # (E_loc,Ce,D)

    y_slots = out_e[e_local, pos] * (w_slots * keep)[:, None].astype(x.dtype)
    yf = jnp.zeros((t, d), x.dtype).at[token_ids].add(y_slots)
    return yf.reshape(b, s, d)


def moe_ffn(params: dict, x: jnp.ndarray, cfg: MoEConfig,
            ctx: L.PhotonicCtx = L.EXACT_CTX, name: str = "moe",
            dist: DistCtx = LOCAL) -> jnp.ndarray:
    """Shared experts + routed experts.  See module docstring."""
    shared = 0.0
    if "shared" in params:
        shared = L.mlp(params["shared"], x, ctx, f"{name}.shared")

    if dist.mesh is None or dist.model_shards == 1:
        routed = _routed_local(params["router"], params["gate"],
                               params["up"], params["down"], x, cfg,
                               n_shards=1, my_shard=0)
        return shared + routed

    from jax.experimental.shard_map import shard_map
    n_shards = dist.model_shards
    dspec = P(dist.data_axes)            # batch sharded, model replicated

    def local_fn(router_w, gate_w, up_w, down_w, xl):
        my = jax.lax.axis_index(dist.model_axis)
        part = _routed_local(router_w, gate_w, up_w, down_w, xl, cfg,
                             n_shards, my)
        return jax.lax.psum(part, dist.model_axis)

    routed = shard_map(
        local_fn, mesh=dist.mesh,
        in_specs=(P(), P(dist.model_axis), P(dist.model_axis),
                  P(dist.model_axis), P(*dspec, None, None)),
        out_specs=P(*dspec, None, None),
        check_rep=False,
    )(params["router"], params["gate"], params["up"], params["down"], x)
    return shared + routed


def load_balance_loss(params: dict, x: jnp.ndarray, cfg: MoEConfig
                      ) -> jnp.ndarray:
    """Switch-style auxiliary load-balancing loss (mean fraction * prob)."""
    t_shape = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])
    probs = jax.nn.softmax(
        (xf @ params["router"].astype(xf.dtype)).astype(jnp.float32), -1)
    top_e = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top_e, cfg.num_experts), axis=0)
    imp = jnp.mean(probs, axis=0)
    del t_shape
    return cfg.num_experts * jnp.sum(frac * imp)

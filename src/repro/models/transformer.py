"""Decoder-only LM assembly: dense / MoE / local-global / SSM / hybrid.

A config resolves to a *layer plan*: a short list of groups, each either a
single irregular layer or a stack of ``repeats`` identical superblocks that
run under ``jax.lax.scan`` (bounds HLO size and — with jax.checkpoint —
activation memory for the 48-81-layer archs).

Covered families:
  dense (qwen2, h2o-danube3, llava/mistral backbone), local:global (gemma3),
  moe+MLA (deepseek v2/v3), ssm (mamba2), hybrid (zamba2: mamba blocks with
  a SHARED attention block applied every k-th position — shared parameters,
  per-position KV cache).

Serving: ``init_caches`` -> ``prefill`` -> ``decode_step`` with explicit
cache pytrees throughout (shardable by launch/serve.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S


# ---------------------------------------------------------------------------
# Layer plans
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Group:
    name: str
    kind: str        # 'attn_dense' | 'attn_moe' | 'mamba' | 'mamba_shared'
    repeats: int     # scan length (1 = single unscanned layer)
    period: Tuple[str, ...] = ()   # sub-layer kinds within one superblock
    windows: Tuple[int, ...] = ()  # per-sub-layer attention window (0=full)


def layer_plan(cfg: ArchConfig) -> List[Group]:
    if cfg.family == "ssm":
        return [Group("mamba", "mamba", cfg.num_layers)]
    if cfg.family == "hybrid":
        p = cfg.shared_attn_period
        full, rem = divmod(cfg.num_layers, p)
        groups = [Group("hybrid", "mamba_shared", full)]
        if rem:
            groups.append(Group("tail", "mamba", rem))
        return groups
    if cfg.local_global_period:
        p = cfg.local_global_period
        assert cfg.num_layers % p == 0, (cfg.num_layers, p)
        wins = tuple(cfg.local_window if i < p - 1 else 0 for i in range(p))
        kinds = tuple("attn_dense" for _ in range(p))
        return [Group("localglobal", "attn_dense", cfg.num_layers // p,
                      period=kinds, windows=wins)]
    if cfg.moe is not None:
        groups = []
        fd = cfg.moe.first_dense_layers
        if fd:
            groups.append(Group("dense_head", "attn_dense", fd))
        groups.append(Group("moe_body", "attn_moe", cfg.num_layers - fd))
        return groups
    return [Group("body", "attn_dense", cfg.num_layers)]


def attn_spec(cfg: ArchConfig, window: int = -1) -> A.AttnSpec:
    return A.AttnSpec(
        d_model=cfg.d_model, num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
        rope_theta=cfg.rope_theta, qkv_bias=cfg.qkv_bias,
        window=(cfg.sliding_window if window < 0 else window),
        mla=cfg.mla, head_pad=cfg.head_pad)


# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------
def _make_sublayer(maker: L.ParamMaker, name: str, cfg: ArchConfig,
                   kind: str, window: int) -> dict:
    if kind == "mamba":
        return {"mamba": S.make_mamba(maker, f"{name}.mamba", cfg.d_model,
                                      cfg.ssm),
                "ln": L.make_rms_norm(maker, f"{name}.ln", cfg.d_model)}
    p = {
        "ln1": L.make_rms_norm(maker, f"{name}.ln1", cfg.d_model),
        "attn": A.make_attention(maker, f"{name}.attn",
                                 attn_spec(cfg, window)),
        "ln2": L.make_rms_norm(maker, f"{name}.ln2", cfg.d_model),
    }
    if kind == "attn_moe":
        p["ffn"] = M.make_moe(maker, f"{name}.ffn", cfg.d_model, cfg.moe)
    else:
        p["ffn"] = L.make_mlp(maker, f"{name}.ffn", cfg.d_model, cfg.d_ff)
    return p


def make_stacked(maker: L.ParamMaker, name: str, n: int, build_fn):
    """Stack n structurally-identical param trees on a leading STACK axis."""
    if maker.spec_mode:
        inner = build_fn(maker, f"{name}.0")
        return jax.tree.map(lambda axes: (L.STACK,) + tuple(axes),
                            inner, is_leaf=lambda x: isinstance(x, tuple))
    parts = [build_fn(maker, f"{name}.{i}") for i in range(n)]
    if maker.abstract:
        return jax.tree.map(
            lambda s, *_: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype),
            parts[0], *parts[1:])
    return jax.tree.map(lambda *xs: jnp.stack(xs), *parts)


def _make_group(maker: L.ParamMaker, cfg: ArchConfig, g: Group) -> dict:
    if g.kind == "mamba_shared":
        def build(mk, nm):
            blocks = {}
            for i in range(cfg.shared_attn_period):
                blocks[f"m{i}"] = _make_sublayer(mk, f"{nm}.m{i}", cfg,
                                                 "mamba", 0)
            return blocks
        p = {"stack": make_stacked(maker, g.name, g.repeats, build)}
        # ONE shared attention block (params reused at every period).
        p["shared_attn"] = {
            "ln1": L.make_rms_norm(maker, f"{g.name}.sh.ln1", cfg.d_model),
            "attn": A.make_attention(maker, f"{g.name}.sh.attn",
                                     attn_spec(cfg)),
            "ln2": L.make_rms_norm(maker, f"{g.name}.sh.ln2", cfg.d_model),
            "ffn": L.make_mlp(maker, f"{g.name}.sh.ffn", cfg.d_model,
                              cfg.d_ff),
        }
        return p
    if g.period:   # local:global superblock
        def build(mk, nm):
            return {f"l{i}": _make_sublayer(mk, f"{nm}.l{i}", cfg,
                                            g.period[i], g.windows[i])
                    for i in range(len(g.period))}
        return {"stack": make_stacked(maker, g.name, g.repeats, build)}

    def build(mk, nm):
        return _make_sublayer(mk, nm, cfg, g.kind, -1)
    return {"stack": make_stacked(maker, g.name, g.repeats, build)}


def init_params(cfg: ArchConfig, key: Optional[jax.Array],
                abstract: bool = False) -> dict:
    """key=None -> logical-axis spec tree (same structure as the params)."""
    maker = L.ParamMaker(key, dtype=jnp.dtype(cfg.dtype), abstract=abstract)
    p: Dict[str, Any] = {
        "embed": L.make_embedding(maker, "embed", cfg.vocab_size,
                                  cfg.d_model),
        "final_ln": L.make_rms_norm(maker, "final_ln", cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = {"table": maker.param(
            "lm_head.table", (cfg.vocab_size, cfg.d_model),
            (L.VOCAB, L.EMBED), scale=cfg.d_model ** -0.5)}
    if cfg.vision_embed_dim:
        p["projector"] = L.make_dense(maker, "projector",
                                      cfg.vision_embed_dim, cfg.d_model,
                                      (None, L.EMBED))
    if cfg.mtp_depth > 0:
        # DeepSeek-V3 multi-token prediction: a combiner + one extra block
        # per depth, sharing the embedding/head (arXiv:2412.19437 §2.2).
        p["mtp"] = {
            "ln_h": L.make_rms_norm(maker, "mtp.ln_h", cfg.d_model),
            "ln_e": L.make_rms_norm(maker, "mtp.ln_e", cfg.d_model),
            "proj": L.make_dense(maker, "mtp.proj", 2 * cfg.d_model,
                                 cfg.d_model, (None, L.EMBED)),
            "block": _make_sublayer(maker, "mtp.block", cfg, "attn_dense",
                                    -1),
            "final_ln": L.make_rms_norm(maker, "mtp.final_ln", cfg.d_model),
        }
    for g in layer_plan(cfg):
        p[g.name] = _make_group(maker, cfg, g)
    return p


def mtp_hidden(params: dict, hidden: jnp.ndarray, tokens: jnp.ndarray,
               cfg: ArchConfig, ctx: L.PhotonicCtx = L.EXACT_CTX,
               dist: M.DistCtx = M.LOCAL) -> jnp.ndarray:
    """Depth-1 MTP trunk: hidden states for predicting token t+2.

    hidden: (B, S, D) main-trunk final hidden; tokens: (B, S).  Returns
    (B, S-1, D) — position t predicts tokens[t+2] (caller aligns targets).
    """
    mp = params["mtp"]
    b, s = tokens.shape
    h = L.rms_norm(mp["ln_h"], hidden[:, :-1])
    e = L.rms_norm(mp["ln_e"], L.embed(params["embed"], tokens[:, 1:]))
    x = L.dense(mp["proj"], jnp.concatenate([h, e], axis=-1), ctx,
                "mtp.proj")
    positions = jnp.broadcast_to(jnp.arange(s - 1, dtype=jnp.int32)[None],
                                 (b, s - 1))
    x, _ = _run_sublayer(mp["block"], x, positions, cfg, "attn_dense", 0,
                         ctx, dist, "mtp.block")
    return L.rms_norm(mp["final_ln"], x)


def param_specs(cfg: ArchConfig) -> dict:
    return init_params(cfg, key=None)


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------
def _run_sublayer(p, x, positions, cfg, kind, window, ctx, dist, name,
                  cache=None, cache_index=None, ssm_impl="jax",
                  return_state=False):
    if kind == "mamba":
        h = L.rms_norm(p["ln"], x)
        if cache_index is not None:
            out, st = S.mamba_decode_step(p["mamba"], h, cfg.d_model, cfg.ssm,
                                          cache, ctx, name)
        else:
            out, st = S.mamba_block(p["mamba"], h, cfg.d_model, cfg.ssm, ctx,
                                    name, state=cache,
                                    return_state=return_state, impl=ssm_impl)
        return x + out, st
    spec = attn_spec(cfg, window)
    h, new_cache = A.attention(p["attn"], L.rms_norm(p["ln1"], x), positions,
                               spec, ctx, f"{name}.attn", cache, cache_index,
                               dist=dist)
    x = x + h
    h2 = L.rms_norm(p["ln2"], x)
    if kind == "attn_moe":
        ff = M.moe_ffn(p["ffn"], h2, cfg.moe, ctx, f"{name}.ffn", dist)
    else:
        ff = L.mlp(p["ffn"], h2, ctx, f"{name}.ffn")
    return x + ff, new_cache


def _scan_group(p, x, positions, cfg, g: Group, ctx, dist, remat: bool,
                caches=None, cache_index=None, ssm_impl="jax",
                return_state=False):
    """Run one plan group; returns (x, new_caches_or_None)."""
    has_cache = caches is not None

    def superblock(x, layer_p, layer_cache, idx):
        new_caches = {}
        if g.kind == "mamba_shared":
            for i in range(cfg.shared_attn_period):
                key = f"m{i}"
                c = layer_cache.get(key) if has_cache else None
                x, nc = _run_sublayer(
                    layer_p[key], x, positions, cfg, "mamba", 0, ctx, dist,
                    f"{g.name}.m{i}", c, cache_index, ssm_impl, return_state)
                new_caches[key] = nc
            c = layer_cache.get("sh") if has_cache else None
            x, nc = _run_sublayer(
                p["shared_attn"], x, positions, cfg, "attn_dense", 0, ctx,
                dist, f"{g.name}.sh", c, cache_index, ssm_impl, return_state)
            new_caches["sh"] = nc
        elif g.period:
            for i, (kind, win) in enumerate(zip(g.period, g.windows)):
                key = f"l{i}"
                c = layer_cache.get(key) if has_cache else None
                x, nc = _run_sublayer(
                    layer_p[key], x, positions, cfg, kind, win, ctx, dist,
                    f"{g.name}.{i}", c, cache_index, ssm_impl, return_state)
                new_caches[key] = nc
        else:
            c = layer_cache if has_cache else None
            x, nc = _run_sublayer(
                layer_p, x, positions, cfg, g.kind, -1, ctx, dist, g.name,
                c, cache_index, ssm_impl, return_state)
            new_caches = nc
        del idx
        return x, new_caches

    # §Perf iteration 5: save matmul outputs across the remat boundary
    # (recomputing elementwise ops is ~free; recomputing dots is ~25% of
    # the step's FLOPs).
    fn = jax.checkpoint(
        superblock,
        policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable) \
        if remat else superblock
    stacked = p["stack"]
    if g.repeats <= 4:
        # Unrolled: avoids lax.scan for short groups.  Also what the
        # roofline probes rely on — XLA cost_analysis counts a scan body
        # ONCE regardless of trip count, so probe configs (1-2 repeats)
        # must be unrolled to measure true per-layer costs.
        new_cache_list = []
        for i in range(g.repeats):
            single = jax.tree.map(lambda a, i=i: a[i], stacked)
            sc = jax.tree.map(lambda a, i=i: a[i], caches) if has_cache \
                else {}
            x, nc = fn(x, single, sc, i)
            new_cache_list.append(nc)
        if new_cache_list[-1] is None or not (has_cache or return_state):
            return x, None
        return x, jax.tree.map(lambda *a: jnp.stack(a), *new_cache_list)

    if not has_cache:
        def body_nocache(carry, xs):
            layer_p, idx = xs
            x2, nc = fn(carry, layer_p, {}, idx)
            return x2, (nc if return_state else None)
        x, ncs = jax.lax.scan(body_nocache, x,
                              (stacked, jnp.arange(g.repeats)))
        return x, (ncs if return_state else None)

    def body(carry, xs):
        layer_p, layer_c, idx = xs
        x2, nc = fn(carry, layer_p, layer_c, idx)
        return x2, nc

    x, new_caches = jax.lax.scan(body, x, (stacked, caches,
                                           jnp.arange(g.repeats)))
    return x, new_caches


def forward(params: dict, tokens: jnp.ndarray, cfg: ArchConfig,
            ctx: L.PhotonicCtx = L.EXACT_CTX, dist: M.DistCtx = M.LOCAL,
            remat: bool = True, ssm_impl: str = "jax",
            prefix_embeds: Optional[jnp.ndarray] = None,
            return_hidden: bool = False) -> jnp.ndarray:
    """Training/scoring forward: tokens (B, S) -> logits (B, S, vocab).

    ``prefix_embeds`` (B, S_img, vision_dim): VLM patch embeddings that are
    projected and OVERWRITE the embeddings of the first S_img positions
    (the assignment's modality-stub contract: frontends provide precomputed
    embeddings; sequence length already includes them).

    ``return_hidden=True`` returns the final-norm hidden states instead of
    logits — the vocab-sharded cross-entropy path consumes these so the
    full logits tensor is never materialized replicated.
    """
    b, s = tokens.shape
    x = L.embed(params["embed"], tokens)
    if prefix_embeds is not None:
        proj = L.dense(params["projector"], prefix_embeds, ctx, "projector")
        n_img = proj.shape[1]
        x = jnp.concatenate([proj.astype(x.dtype), x[:, n_img:]], axis=1)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    for g in layer_plan(cfg):
        x, _ = _scan_group(params[g.name], x, positions, cfg, g, ctx, dist,
                           remat, ssm_impl=ssm_impl)
    x = L.rms_norm(params["final_ln"], x)
    if return_hidden:
        return x
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return L.unembed(head, x, ctx)


# ---------------------------------------------------------------------------
# Serving: caches, prefill, decode
# ---------------------------------------------------------------------------
def init_caches(cfg: ArchConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16) -> dict:
    caches = {}
    for g in layer_plan(cfg):
        def one(kind: str, window: int):
            if kind == "mamba":
                return S.init_state(cfg.d_model, cfg.ssm, batch)
            return A.init_cache(attn_spec(cfg, window), batch, max_len, dtype)

        if g.kind == "mamba_shared":
            block = {f"m{i}": one("mamba", 0)
                     for i in range(cfg.shared_attn_period)}
            block["sh"] = one("attn_dense", 0)
        elif g.period:
            block = {f"l{i}": one(g.period[i], g.windows[i])
                     for i in range(len(g.period))}
        else:
            block = one(g.kind, cfg.sliding_window if g.kind != "mamba"
                        else 0)
        caches[g.name] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (g.repeats,) + a.shape)
            if g.repeats >= 1 else a, block)
    return caches


def prefill(params: dict, tokens: jnp.ndarray, cfg: ArchConfig,
            caches: dict, ctx: L.PhotonicCtx = L.EXACT_CTX,
            dist: M.DistCtx = M.LOCAL, ssm_impl: str = "jax",
            prefix_embeds: Optional[jnp.ndarray] = None
            ) -> Tuple[jnp.ndarray, dict]:
    """Fill caches from a prompt; returns (last-token logits, caches)."""
    b, s = tokens.shape
    x = L.embed(params["embed"], tokens)
    if prefix_embeds is not None:
        proj = L.dense(params["projector"], prefix_embeds, ctx, "projector")
        x = jnp.concatenate([proj.astype(x.dtype), x[:, proj.shape[1]:]], 1)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    new_caches = {}
    for g in layer_plan(cfg):
        x, nc = _scan_group(params[g.name], x, positions, cfg, g, ctx, dist,
                            remat=False, caches=caches[g.name],
                            cache_index=None, ssm_impl=ssm_impl,
                            return_state=True)
        new_caches[g.name] = nc
    x = L.rms_norm(params["final_ln"], x[:, -1:])
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return L.unembed(head, x, ctx), new_caches


def decode_step(params: dict, token: jnp.ndarray, index: jnp.ndarray,
                cfg: ArchConfig, caches: dict,
                ctx: L.PhotonicCtx = L.EXACT_CTX,
                dist: M.DistCtx = M.LOCAL) -> Tuple[jnp.ndarray, dict]:
    """One decode step.  token: (B, 1) int32; index: scalar position."""
    b = token.shape[0]
    x = L.embed(params["embed"], token)
    positions = jnp.full((b, 1), index, jnp.int32)
    new_caches = {}
    for g in layer_plan(cfg):
        x, nc = _scan_group(params[g.name], x, positions, cfg, g, ctx, dist,
                            remat=False, caches=caches[g.name],
                            cache_index=index)
        new_caches[g.name] = nc
    x = L.rms_norm(params["final_ln"], x)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return L.unembed(head, x, ctx), new_caches

"""General CNN lowering IR: op graphs lowered to im2col GEMMs + glue.

HEANA consumes convolution networks as GEMMs via the Toeplitz/im2col
transform (paper §2.1); everything *between* the GEMMs — pooling,
residual adds, branch concats, channel shuffles — is cheap digital glue
handled by the accelerator tile's post-GEMM units (Fig. 10).  This
module is the single source of truth for that lowering, shared by:

  * the executor (repro.exec.executor), which replays a graph through
    the Pallas kernel with per-layer plans and noise keys;
  * the pure-jnp oracle (repro.exec.reference_forward), which replays
    the SAME graph through kernels/ref.py;
  * the analytic side (``graph_gemms``), which emits the per-layer
    LayerGemm table the scheduler and perf model consume — so planned
    shapes and executed shapes cannot drift.

The IR is a flat topologically-ordered tuple of ``OpNode``s (an
``OpGraph``).  Node kinds:

  ``input``           the graph input (carries C_in in ``cout``)
  ``conv``            kh x kw conv, stride/padding, -> im2col GEMM
                      with K = kh*kw*C_in, D = cout
  ``depthwise_conv``  per-channel kh x kw conv -> ONE block-diagonal
                      GEMM (K = kh*kw*C, D = C); accounted analytically
                      as ``count=C`` grouped (kh*kw, 1) GEMMs, matching
                      the paper's depthwise tables
  ``pool``            max / avg / global — glue, no GEMM
  ``residual_add``    elementwise sum of two same-shape producers
  ``concat``          channel concat of >= 2 producers
  ``shuffle``         ShuffleNet channel shuffle (``groups``)
  ``slice``           channel slice [c_lo, c_hi) (ShuffleNet split)
  ``fc``              flatten -> (K, D) GEMM

Graphs are frozen and hashable by value so they can sit directly in
jax.jit static arguments (the executor bakes the graph into the traced
program exactly like the plan's tilings).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

GEMM_OPS = ("conv", "depthwise_conv", "fc")
GLUE_OPS = ("pool", "residual_add", "concat", "shuffle", "slice")
OPS = ("input",) + GEMM_OPS + GLUE_OPS
POOL_KINDS = ("max", "avg", "global")
PADDINGS = ("same", "valid")


# ---------------------------------------------------------------------------
# Analytic GEMM record (the scheduler/perf-model currency)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class LayerGemm:
    """One layer as an im2col GEMM: I (C x K) @ W (K x D), ``count``
    parallel instances (depthwise groups)."""
    name: str
    c: int      # output pixels (rows of I)
    k: int      # C_in * kh * kw (contraction)
    d: int      # output channels
    count: int = 1   # parallel instances (e.g. depthwise groups)

    @property
    def macs(self) -> int:
        return self.c * self.k * self.d * self.count

    @property
    def executed(self) -> Tuple[int, int, int]:
        """The (M, K, D) of the ONE GEMM the executor actually runs.

        This is the single home of the fusion convention: depthwise
        layers (count > 1, d == 1 — what graph_gemms emits for
        ``depthwise_conv`` nodes) are executed as one block-diagonal
        GEMM (depthwise_block_diag), so K and D scale by count; every
        other layer executes its analytic shape as-is.  The scheduler
        sizes kernel tiles and the executor reports traces against
        THESE dims — do not re-derive the convention elsewhere.
        """
        if self.count > 1 and self.d == 1:
            return (self.c, self.k * self.count, self.count)
        return (self.c, self.k, self.d)


@dataclasses.dataclass(frozen=True)
class OpNode:
    """One node of a lowered CNN graph.  Only the fields relevant to
    ``op`` are read; the rest keep their defaults (the builder helpers
    below construct well-formed nodes)."""
    name: str
    op: str
    inputs: Tuple[str, ...] = ()
    cout: int = 0          # conv/fc output channels; input: C_in
    kh: int = 3            # conv/depthwise kernel size
    kw: int = 3
    stride: int = 1        # conv/depthwise stride
    padding: str = "same"  # conv/depthwise/pool: 'same' | 'valid'
    relu: bool = False     # ReLU after the op (post-GEMM activation unit)
    pool: str = "max"      # pool kind: 'max' | 'avg' | 'global'
    pool_size: int = 2
    pool_stride: int = 2
    groups: int = 2        # shuffle groups
    c_lo: int = 0          # slice channel range [c_lo, c_hi)
    c_hi: int = 0


@dataclasses.dataclass(frozen=True)
class OpGraph:
    """Topologically-ordered node tuple; the last node is the output.

    Validated at construction: unique names, known ops, every input
    referencing an EARLIER node, per-op arity.  Hashable by value (all
    fields are frozen/hashable) — a valid static jax.jit argument.
    """
    nodes: Tuple[OpNode, ...]

    def __post_init__(self):
        object.__setattr__(self, "nodes", tuple(self.nodes))
        if not self.nodes:
            raise ValueError("OpGraph needs at least one node")
        seen = set()
        for i, n in enumerate(self.nodes):
            if n.op not in OPS:
                raise ValueError(f"{n.name}: unknown op {n.op!r} "
                                 f"(known: {OPS})")
            if n.name in seen:
                raise ValueError(f"duplicate node name {n.name!r}")
            seen.add(n.name)
            if n.op == "input":
                if i != 0:
                    raise ValueError(
                        f"{n.name}: 'input' must be the first node")
                if n.inputs:
                    raise ValueError(f"{n.name}: 'input' takes no inputs")
                if n.cout < 1:
                    raise ValueError(
                        f"{n.name}: input node carries C_in in cout, "
                        f"got {n.cout}")
                continue
            want = (2 if n.op == "residual_add"
                    else None if n.op == "concat" else 1)
            if want is not None and len(n.inputs) != want:
                raise ValueError(
                    f"{n.name}: op {n.op!r} takes {want} input(s), "
                    f"got {len(n.inputs)}")
            if n.op == "concat" and len(n.inputs) < 2:
                raise ValueError(f"{n.name}: concat needs >= 2 inputs")
            for src in n.inputs:
                if src not in seen:
                    raise ValueError(
                        f"{n.name}: input {src!r} is not an earlier node "
                        f"(graphs are topologically ordered)")
            if n.op in ("conv", "depthwise_conv"):
                if n.kh < 1 or n.kw < 1 or n.stride < 1:
                    raise ValueError(
                        f"{n.name}: kernel {n.kh}x{n.kw} stride {n.stride} "
                        f"must all be >= 1")
                if n.padding not in PADDINGS:
                    raise ValueError(f"{n.name}: padding {n.padding!r} "
                                     f"not in {PADDINGS}")
            if n.op == "conv" and n.cout < 1:
                raise ValueError(f"{n.name}: conv needs cout >= 1")
            if n.op == "fc" and n.cout < 1:
                raise ValueError(f"{n.name}: fc needs cout >= 1")
            if n.op == "pool":
                if n.pool not in POOL_KINDS:
                    raise ValueError(f"{n.name}: pool kind {n.pool!r} "
                                     f"not in {POOL_KINDS}")
                if n.pool != "global" and (n.pool_size < 1
                                          or n.pool_stride < 1):
                    raise ValueError(
                        f"{n.name}: pool_size/pool_stride must be >= 1")
                if n.pool == "avg" and n.padding == "same" \
                        and n.pool_size > 1:
                    raise ValueError(
                        f"{n.name}: 'same'-padded avg pool is ambiguous "
                        f"(padding in the divisor) — use 'valid' or max")
            if n.op == "slice" and not 0 <= n.c_lo < n.c_hi:
                raise ValueError(
                    f"{n.name}: slice needs 0 <= c_lo < c_hi, got "
                    f"[{n.c_lo}, {n.c_hi})")
            if n.op == "shuffle" and n.groups < 1:
                raise ValueError(f"{n.name}: shuffle groups must be >= 1")

    @property
    def input(self) -> OpNode:
        return self.nodes[0]

    @property
    def output(self) -> OpNode:
        return self.nodes[-1]

    @property
    def gemm_nodes(self) -> Tuple[OpNode, ...]:
        return tuple(n for n in self.nodes if n.op in GEMM_OPS)

    def node(self, name: str) -> OpNode:
        for n in self.nodes:
            if n.name == name:
                return n
        raise KeyError(name)


# ---------------------------------------------------------------------------
# Builder helpers (terse, well-formed nodes)
# ---------------------------------------------------------------------------
def input_node(cin: int, name: str = "input") -> OpNode:
    return OpNode(name, "input", cout=cin)


def conv(name, src, cout, kk=3, stride=1, relu=True,
         padding="same") -> OpNode:
    return OpNode(name, "conv", (src,), cout=cout, kh=kk, kw=kk,
                  stride=stride, relu=relu, padding=padding)


def dwconv(name, src, kk=3, stride=1, relu=False,
           padding="same") -> OpNode:
    return OpNode(name, "depthwise_conv", (src,), kh=kk, kw=kk,
                  stride=stride, relu=relu, padding=padding)


def pool(name, src, kind="max", size=2, stride=2,
         padding="valid") -> OpNode:
    return OpNode(name, "pool", (src,), pool=kind, pool_size=size,
                  pool_stride=stride, padding=padding)


def global_avg(name, src) -> OpNode:
    return OpNode(name, "pool", (src,), pool="global")


def residual(name, a, b, relu=True) -> OpNode:
    return OpNode(name, "residual_add", (a, b), relu=relu)


def concat(name, *srcs) -> OpNode:
    return OpNode(name, "concat", tuple(srcs))


def shuffle(name, src, groups=2) -> OpNode:
    return OpNode(name, "shuffle", (src,), groups=groups)


def slice_ch(name, src, lo, hi) -> OpNode:
    return OpNode(name, "slice", (src,), c_lo=lo, c_hi=hi)


def fc(name, src, cout, relu=False) -> OpNode:
    return OpNode(name, "fc", (src,), cout=cout, relu=relu)


# ---------------------------------------------------------------------------
# Spatial arithmetic + shape inference
# ---------------------------------------------------------------------------
def spatial_dims(in_hw) -> Tuple[int, int]:
    """Normalize a spatial-size spec: int -> square, (H, W) -> as given.

    Validates explicitly — a bad spec used to surface as reshape noise
    deep inside the walk."""
    if isinstance(in_hw, (tuple, list)):
        if len(in_hw) != 2:
            raise ValueError(
                f"in_hw must be an int or an (H, W) pair, got "
                f"{tuple(in_hw)!r}")
        h, w = int(in_hw[0]), int(in_hw[1])
    else:
        h = w = int(in_hw)
    if h < 1 or w < 1:
        raise ValueError(f"in_hw must be positive, got {h}x{w}")
    return h, w


def conv_out_dim(size: int, k: int, stride: int, padding: str) -> int:
    """Output extent of one spatial axis (TF/XLA SAME/VALID semantics)."""
    if padding == "same":
        return -(-size // stride)
    if size < k:
        raise ValueError(
            f"'valid' window k={k} does not fit in extent {size} — pad "
            f"the input or use padding='same'")
    return (size - k) // stride + 1


def _pool_out(node: OpNode, h: int, w: int) -> Tuple[int, int]:
    if node.pool == "global":
        return 1, 1
    s, st = node.pool_size, node.pool_stride
    if node.padding == "same":
        return -(-h // st), -(-w // st)
    for dim, tag in ((h, "H"), (w, "W")):
        if dim < s or (dim - s) % st:
            raise ValueError(
                f"{node.name}: 'valid' {s}x{s}/{st} pool does not tile "
                f"{tag}={dim} (needs {tag} >= {s} and ({tag} - {s}) "
                f"divisible by {st}) — odd/indivisible dims must be "
                f"handled explicitly: use padding='same', a global pool, "
                f"or resize the input")
    return (h - s) // st + 1, (w - s) // st + 1


def infer_shapes(graph: OpGraph, in_hw,
                 params: Optional[dict] = None
                 ) -> Dict[str, Tuple[int, int, int]]:
    """Per-node output shapes (H, W, C) for a given input spatial size.

    Channels come from node attrs (``cout``); when ``params`` is given,
    every GEMM weight shape is validated against the inferred one with a
    clear error.
    """
    h, w = spatial_dims(in_hw)
    shapes: Dict[str, Tuple[int, int, int]] = {}
    for n in graph.nodes:
        if n.op == "input":
            shapes[n.name] = (h, w, n.cout)
            continue
        ih, iw, ic = shapes[n.inputs[0]]
        if n.op in ("conv", "depthwise_conv"):
            oh = conv_out_dim(ih, n.kh, n.stride, n.padding)
            ow = conv_out_dim(iw, n.kw, n.stride, n.padding)
            oc = ic if n.op == "depthwise_conv" else n.cout
            want = ((n.kh * n.kw, ic) if n.op == "depthwise_conv"
                    else (n.kh * n.kw * ic, oc))
            shapes[n.name] = (oh, ow, oc)
        elif n.op == "fc":
            oc = n.cout
            want = (ih * iw * ic, oc)
            shapes[n.name] = (1, 1, oc)
        elif n.op == "pool":
            oh, ow = _pool_out(n, ih, iw)
            shapes[n.name] = (oh, ow, ic)
        elif n.op == "residual_add":
            other = shapes[n.inputs[1]]
            if other != (ih, iw, ic):
                raise ValueError(
                    f"{n.name}: residual_add inputs disagree — "
                    f"{n.inputs[0]} is {(ih, iw, ic)} but {n.inputs[1]} "
                    f"is {other}")
            shapes[n.name] = (ih, iw, ic)
        elif n.op == "concat":
            cs = 0
            for src in n.inputs:
                sh, sw, sc = shapes[src]
                if (sh, sw) != (ih, iw):
                    raise ValueError(
                        f"{n.name}: concat inputs disagree spatially — "
                        f"{n.inputs[0]} is {ih}x{iw} but {src} is "
                        f"{sh}x{sw}")
                cs += sc
            shapes[n.name] = (ih, iw, cs)
        elif n.op == "shuffle":
            if ic % n.groups:
                raise ValueError(
                    f"{n.name}: shuffle groups={n.groups} does not divide "
                    f"C={ic}")
            shapes[n.name] = (ih, iw, ic)
        elif n.op == "slice":
            if n.c_hi > ic:
                raise ValueError(
                    f"{n.name}: slice [{n.c_lo}, {n.c_hi}) exceeds C={ic}")
            shapes[n.name] = (ih, iw, n.c_hi - n.c_lo)
        if n.op in GEMM_OPS and params is not None:
            got = tuple(params[n.name].shape)
            if got != want:
                raise ValueError(
                    f"{n.name}: weight shape {got} but the graph at this "
                    f"node implies {want} (in_hw mismatch, or params from "
                    f"a different graph)")
    return shapes


# ---------------------------------------------------------------------------
# im2col (general stride/padding; the stride-1 'same' case is bit-
# identical to the original models.cnn._im2col)
# ---------------------------------------------------------------------------
def im2col(x: jnp.ndarray, kh: int, kw: int, stride: int = 1,
           padding: str = "same") -> Tuple[jnp.ndarray, Tuple[int, int]]:
    """NHWC -> ((N, OH*OW, kh*kw*C) patches, (OH, OW)).

    K is ordered patch-position-major, channel-minor — the same layout
    ``weight_hwio`` expects and build_* initializers produce.
    """
    n, h, w, c = x.shape
    oh = conv_out_dim(h, kh, stride, padding)
    ow = conv_out_dim(w, kw, stride, padding)
    if padding == "same":
        ph = max((oh - 1) * stride + kh - h, 0)
        pw = max((ow - 1) * stride + kw - w, 0)
        x = jnp.pad(x, ((0, 0), (ph // 2, ph - ph // 2),
                        (pw // 2, pw - pw // 2), (0, 0)))
    patches = [x[:, i:i + (oh - 1) * stride + 1:stride,
                 j:j + (ow - 1) * stride + 1:stride, :]
               for i in range(kh) for j in range(kw)]
    cols = jnp.concatenate(patches, axis=-1).reshape(n, oh * ow,
                                                     kh * kw * c)
    return cols, (oh, ow)


def depthwise_block_diag(w: jnp.ndarray) -> jnp.ndarray:
    """Expand a compact depthwise weight (kh*kw, C) into the block-
    diagonal GEMM operand (kh*kw*C, C) matching im2col's K layout
    (position-major, channel-minor): B[q*C + c, c] = w[q, c]."""
    kkq, c = w.shape
    eye = jnp.eye(c, dtype=w.dtype)
    return (w[:, :, None] * eye[None, :, :]).reshape(kkq * c, c)


def weight_hwio(node: OpNode, w: jnp.ndarray) -> jnp.ndarray:
    """A node's GEMM weight as the HWIO tensor lax.conv expects."""
    if node.op == "depthwise_conv":
        return w.reshape(node.kh, node.kw, 1, w.shape[-1])
    cin = w.shape[0] // (node.kh * node.kw)
    return w.reshape(node.kh, node.kw, cin, w.shape[-1])


# ---------------------------------------------------------------------------
# Parameter init (weight shapes derived from the graph — one source
# of truth; build_* helpers cannot drift from what the walker reads)
# ---------------------------------------------------------------------------
def init_params(graph: OpGraph, key: jax.Array, in_hw=32,
                dtype=jnp.float32) -> Dict[str, jnp.ndarray]:
    """Glorot-style init of every GEMM node's weight, shapes inferred."""
    shapes = infer_shapes(graph, in_hw)
    params: Dict[str, jnp.ndarray] = {}
    prev: Dict[str, Tuple[int, int, int]] = shapes
    for n in graph.gemm_nodes:
        ih, iw, ic = prev[n.inputs[0]]
        if n.op == "conv":
            shape = (n.kh * n.kw * ic, n.cout)
        elif n.op == "depthwise_conv":
            shape = (n.kh * n.kw, ic)
        else:
            shape = (ih * iw * ic, n.cout)
        key, sub = jax.random.split(key)
        params[n.name] = (jax.random.normal(sub, shape, dtype)
                          / jnp.sqrt(shape[0]))
    return params


# ---------------------------------------------------------------------------
# Analytic GEMM table (what the scheduler/perf model plan against)
# ---------------------------------------------------------------------------
def graph_gemms(graph: OpGraph, in_hw,
                params: Optional[dict] = None) -> List[LayerGemm]:
    """The graph's GEMM-bearing nodes as paper-convention LayerGemms.

    conv:      (OH*OW, kh*kw*C_in, C_out)
    depthwise: count=C instances of (OH*OW, kh*kw, 1) — the paper's
               grouped accounting (models.cnn._dw); the executor fuses
               them into one block-diagonal GEMM, same MACs modulo the
               structural zeros it streams.
    fc:        (1, H*W*C, D)

    Order matches the executor's walk exactly — schedule_cnn over this
    list yields plans the executor consumes positionally.
    """
    shapes = infer_shapes(graph, in_hw, params=params)
    out: List[LayerGemm] = []
    for n in graph.gemm_nodes:
        ih, iw, ic = shapes[n.inputs[0]]
        oh, ow, oc = shapes[n.name]
        if n.op == "conv":
            out.append(LayerGemm(n.name, oh * ow, n.kh * n.kw * ic, oc))
        elif n.op == "depthwise_conv":
            out.append(LayerGemm(n.name, oh * ow, n.kh * n.kw, 1,
                                 count=ic))
        else:
            out.append(LayerGemm(n.name, 1, ih * iw * ic, oc))
    return out


# ---------------------------------------------------------------------------
# Forward walkers
# ---------------------------------------------------------------------------
def _apply_pool(node: OpNode, x: jnp.ndarray) -> jnp.ndarray:
    if node.pool == "global":
        return jnp.mean(x, axis=(1, 2), keepdims=True)
    s, st = node.pool_size, node.pool_stride
    pad = "SAME" if node.padding == "same" else "VALID"
    if node.pool == "max":
        return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                     (1, s, s, 1), (1, st, st, 1), pad)
    return jax.lax.reduce_window(x, 0.0, jax.lax.add, (1, s, s, 1),
                                 (1, st, st, 1), pad) / float(s * s)


def _apply_shuffle(node: OpNode, x: jnp.ndarray) -> jnp.ndarray:
    n, h, w, c = x.shape
    g = node.groups
    return x.reshape(n, h, w, g, c // g).swapaxes(3, 4).reshape(n, h, w, c)


def _apply_glue(node: OpNode, a: jnp.ndarray,
                vals: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """The non-GEMM ops, shared by BOTH walkers (graph_forward and
    direct_forward) — glue semantics cannot diverge between the lowered
    path and the direct reference."""
    if node.op == "pool":
        return _apply_pool(node, a)
    if node.op == "residual_add":
        return a + vals[node.inputs[1]]
    if node.op == "concat":
        return jnp.concatenate([vals[s] for s in node.inputs], axis=-1)
    if node.op == "shuffle":
        return _apply_shuffle(node, a)
    if node.op == "slice":
        return a[..., node.c_lo:node.c_hi]
    raise ValueError(f"unknown op {node.op!r}")    # pragma: no cover


def graph_forward(params: dict, x: jnp.ndarray, graph: OpGraph,
                  mm: Callable[[jnp.ndarray, jnp.ndarray, int, OpNode],
                               jnp.ndarray]
                  ) -> Dict[str, jnp.ndarray]:
    """Walk the graph; returns every node's output by name.

    ``mm(cols2d, weight, gemm_index, node)`` runs one lowered GEMM —
    the executor plugs the photonic kernel + per-layer plan/noise key in
    here; ``graph_apply`` plugs a plain (or photonic-reference) matmul.
    All shape bookkeeping is static Python, so the walk traces into a
    single jax.jit program with zero host syncs.
    """
    n = x.shape[0]
    vals: Dict[str, jnp.ndarray] = {}
    gi = 0
    for node in graph.nodes:
        if node.op == "input":
            vals[node.name] = x
            continue
        a = vals[node.inputs[0]]
        if node.op in ("conv", "depthwise_conv"):
            wgt = params[node.name]
            w2d = (depthwise_block_diag(wgt)
                   if node.op == "depthwise_conv" else wgt)
            cols, (oh, ow) = im2col(a, node.kh, node.kw, node.stride,
                                    node.padding)
            out = mm(cols.reshape(-1, cols.shape[-1]), w2d, gi, node)
            y = out.reshape(n, oh, ow, w2d.shape[-1])
            gi += 1
        elif node.op == "fc":
            y = mm(a.reshape(n, -1), params[node.name], gi, node)
            gi += 1
        else:
            y = _apply_glue(node, a, vals)
        if node.relu:
            y = jax.nn.relu(y)
        vals[node.name] = y
    return vals


def graph_apply(params: dict, x: jnp.ndarray, graph: OpGraph,
                matmul: Optional[Callable] = None) -> jnp.ndarray:
    """Forward pass of a lowered graph with a plain ``matmul(a, w)``
    (default exact; pass the photonic simulation for noisy numerics)."""
    base = matmul or (lambda a, w: a @ w)
    vals = graph_forward(params, x, graph,
                         lambda a, w, i, node: base(a, w))
    return vals[graph.output.name]


def direct_forward(params: dict, x: jnp.ndarray,
                   graph: OpGraph) -> jnp.ndarray:
    """Reference forward that does NOT lower to GEMMs: convolutions via
    jax.lax.conv_general_dilated (depthwise via feature_group_count).
    The property suite pins ``graph_apply == direct_forward`` — i.e. the
    im2col/block-diagonal lowering itself is correct for every stride,
    padding, rectangle and branch structure."""
    vals: Dict[str, jnp.ndarray] = {}
    for node in graph.nodes:
        if node.op == "input":
            vals[node.name] = x
            continue
        a = vals[node.inputs[0]]
        if node.op in ("conv", "depthwise_conv"):
            w = weight_hwio(node, params[node.name])
            y = jax.lax.conv_general_dilated(
                a, w, (node.stride, node.stride), node.padding.upper(),
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
                feature_group_count=(a.shape[-1]
                                     if node.op == "depthwise_conv"
                                     else 1))
        elif node.op == "fc":
            y = a.reshape(a.shape[0], -1) @ params[node.name]
        else:
            y = _apply_glue(node, a, vals)
        if node.relu:
            y = jax.nn.relu(y)
        vals[node.name] = y
    return vals[graph.output.name]

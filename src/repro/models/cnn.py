"""The paper's CNN workloads as im2col GEMM tables (+ a runnable small CNN).

The paper's simulator consumes convolution layers as GEMMs via the Toeplitz
/ im2col transform (paper §2.1): a conv with C_in input channels, k x k
kernel, C_out filters and H_out x W_out output pixels becomes

    I (C x K) @ W (K x D)   with  C = H_out * W_out,
                                  K = C_in * k * k,
                                  D = C_out.

Depthwise convolutions are grouped GEMMs: ``count`` instances of a
(C, k*k, 1) GEMM.  All four evaluation CNNs (GoogleNet, ResNet50,
MobileNetV2, ShuffleNetV2 — paper §6.2) are generated below from their
published block structures at 224x224 input.

``build_small_cnn``/``small_cnn_apply`` additionally provide a *runnable*
(forward-pass) CNN used by the Table 4 accuracy experiments, whose conv
layers execute through the photonic GEMM simulation.

Runnable lowerings come in two shapes:

  * the general op-graph IR (models.lowering.OpGraph) — stride/padding
    convs, depthwise convs, pooling, residual adds, concats, channel
    shuffles; the paper's four evaluation networks have reduced-scale
    runnable variants built on it in models.zoo_cnn;
  * the legacy flat ``LoweredLayer`` tuple (conv/fc chains), kept as a
    convenience and converted to a graph internally (``as_graph``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.models import lowering as lw
# Re-exported: LayerGemm's home is the lowering IR now (single source of
# truth for analytic tables AND runnable graphs), but every historical
# importer uses models.cnn.LayerGemm.
from repro.models.lowering import LayerGemm, OpGraph  # noqa: F401


def _conv(name, hw, cin, kk, cout, count=1) -> LayerGemm:
    return LayerGemm(name, hw * hw, cin * kk * kk, cout, count)


def _dw(name, hw, ch, kk=3) -> LayerGemm:
    # depthwise: per-channel (C, kk*kk, 1) GEMMs
    return LayerGemm(name, hw * hw, kk * kk, 1, count=ch)


def googlenet() -> List[LayerGemm]:
    L: List[LayerGemm] = [
        _conv("conv1", 112, 3, 7, 64),
        _conv("conv2_reduce", 56, 64, 1, 64),
        _conv("conv2", 56, 64, 3, 192),
    ]
    # (hw, c_in, 1x1, r3, 3x3, r5, 5x5, pool_proj)
    inception = [
        ("3a", 28, 192, 64, 96, 128, 16, 32, 32),
        ("3b", 28, 256, 128, 128, 192, 32, 96, 64),
        ("4a", 14, 480, 192, 96, 208, 16, 48, 64),
        ("4b", 14, 512, 160, 112, 224, 24, 64, 64),
        ("4c", 14, 512, 128, 128, 256, 24, 64, 64),
        ("4d", 14, 512, 112, 144, 288, 32, 64, 64),
        ("4e", 14, 528, 256, 160, 320, 32, 128, 128),
        ("5a", 7, 832, 256, 160, 320, 32, 128, 128),
        ("5b", 7, 832, 384, 192, 384, 48, 128, 128),
    ]
    for tag, hw, cin, b1, r3, b3, r5, b5, pp in inception:
        L += [
            _conv(f"inc{tag}_1x1", hw, cin, 1, b1),
            _conv(f"inc{tag}_3x3r", hw, cin, 1, r3),
            _conv(f"inc{tag}_3x3", hw, r3, 3, b3),
            _conv(f"inc{tag}_5x5r", hw, cin, 1, r5),
            _conv(f"inc{tag}_5x5", hw, r5, 5, b5),
            _conv(f"inc{tag}_pool", hw, cin, 1, pp),
        ]
    L.append(LayerGemm("fc", 1, 1024, 1000))
    return L


def googlenet_layer5() -> LayerGemm:
    """'Layer 5 of GoogleNet' used by the paper's Fig. 1 buffer-access table
    (5th conv layer = the inception-3a 3x3 branch)."""
    return next(l for l in googlenet() if l.name == "inc3a_3x3")


def resnet50() -> List[LayerGemm]:
    L = [_conv("conv1", 112, 3, 7, 64)]
    stages = [  # (hw_out, c_in_first, width, c_out, blocks)
        (56, 64, 64, 256, 3),
        (28, 256, 128, 512, 4),
        (14, 512, 256, 1024, 6),
        (7, 1024, 512, 2048, 3),
    ]
    for hw, cin_first, wdt, cout, blocks in stages:
        for bi in range(blocks):
            cin = cin_first if bi == 0 else cout
            tag = f"s{hw}b{bi}"
            L += [
                _conv(f"{tag}_1x1a", hw, cin, 1, wdt),
                _conv(f"{tag}_3x3", hw, wdt, 3, wdt),
                _conv(f"{tag}_1x1b", hw, wdt, 1, cout),
            ]
            if bi == 0:
                L.append(_conv(f"{tag}_ds", hw, cin, 1, cout))
    L.append(LayerGemm("fc", 1, 2048, 1000))
    return L


def mobilenet_v2() -> List[LayerGemm]:
    L = [_conv("conv1", 112, 3, 3, 32)]
    # (expansion t, c_out, repeats n, hw_out_of_first_block)
    cfg = [(1, 16, 1, 112), (6, 24, 2, 56), (6, 32, 3, 28), (6, 64, 4, 14),
           (6, 96, 3, 14), (6, 160, 3, 7), (6, 320, 1, 7)]
    cin, hw_in = 32, 112
    for t, cout, n, hw_out in cfg:
        for bi in range(n):
            hw = hw_out if bi == 0 else hw_out
            hidden = cin * t
            tag = f"mb{cout}_{bi}"
            if t > 1:
                L.append(_conv(f"{tag}_expand", hw_in if bi == 0 else hw,
                               cin, 1, hidden))
            L.append(_dw(f"{tag}_dw", hw, hidden))
            L.append(_conv(f"{tag}_project", hw, hidden, 1, cout))
            cin, hw_in = cout, hw
    L.append(_conv("conv_last", 7, 320, 1, 1280))
    L.append(LayerGemm("fc", 1, 1280, 1000))
    return L


def shufflenet_v2() -> List[LayerGemm]:
    L = [_conv("conv1", 112, 3, 3, 24)]
    stages = [  # (hw_out, c_in, c_branch, blocks)
        (28, 24, 58, 4),
        (14, 116, 116, 8),
        (7, 232, 232, 4),
    ]
    for hw, cin, cb, blocks in stages:
        hw_in = hw * 2
        # stride-2 block: two branches
        L += [
            _dw(f"sh{hw}s2_b1dw", hw, cin),
            _conv(f"sh{hw}s2_b1pw", hw, cin, 1, cb),
            _conv(f"sh{hw}s2_b2pw1", hw_in, cin, 1, cb),
            _dw(f"sh{hw}s2_b2dw", hw, cb),
            _conv(f"sh{hw}s2_b2pw2", hw, cb, 1, cb),
        ]
        for bi in range(1, blocks):
            L += [
                _conv(f"sh{hw}b{bi}_pw1", hw, cb, 1, cb),
                _dw(f"sh{hw}b{bi}_dw", hw, cb),
                _conv(f"sh{hw}b{bi}_pw2", hw, cb, 1, cb),
            ]
    L.append(_conv("conv5", 7, 464, 1, 1024))
    L.append(LayerGemm("fc", 1, 1024, 1000))
    return L


CNN_ZOO: Dict[str, Callable[[], List[LayerGemm]]] = {
    "googlenet": googlenet,
    "resnet50": resnet50,
    "mobilenet_v2": mobilenet_v2,
    "shufflenet_v2": shufflenet_v2,
}


def total_macs(layers: List[LayerGemm]) -> int:
    return sum(l.macs for l in layers)


# ---------------------------------------------------------------------------
# GEMM lowering hooks (consumed by repro.exec — the execution engine)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class LoweredLayer:
    """One GEMM-lowered layer of a *runnable* CNN.

    ``name`` doubles as the params-dict key holding the (K, D) weight
    matrix.  ``kind`` selects the input transform: 'conv' applies the
    kk x kk im2col (SAME padding, stride 1) before the GEMM; 'fc' flattens
    the feature map into a single row per image.  ``relu``/``pool_after``
    describe the digital post-GEMM stages (activation unit / pooling unit
    in the accelerator's tile, Fig. 10).
    """
    name: str
    kind: str                 # 'conv' | 'fc'
    relu: bool = True
    pool_after: bool = False  # 2x2 max pool, stride 2
    kk: int = 3


def small_cnn_lowering() -> tuple:
    """The GEMM-lowering of build_small_cnn/small_cnn_apply, layer by layer.

    Kept next to the forward pass so the two cannot drift: the executor
    (repro.exec.executor) replays exactly this structure through the Pallas
    kernel, and tests pin it against small_cnn_apply.  This is the legacy
    flat form; ``small_cnn_graph`` is the same network as op-graph IR.
    """
    return (
        LoweredLayer("conv1", "conv", relu=True, pool_after=True),
        LoweredLayer("conv2", "conv", relu=True, pool_after=True),
        LoweredLayer("conv3", "conv", relu=True, pool_after=False),
        LoweredLayer("fc", "fc", relu=False, pool_after=False),
    )


def small_cnn_graph(num_classes: int = 10, in_ch: int = 3) -> OpGraph:
    """build_small_cnn as op-graph IR (identical numerics to the legacy
    flat lowering: conv-relu-pool, conv-relu-pool, conv-relu, fc)."""
    return OpGraph((
        lw.input_node(in_ch),
        lw.conv("conv1", "input", 16),
        lw.pool("conv1.pool", "conv1"),
        lw.conv("conv2", "conv1.pool", 32),
        lw.pool("conv2.pool", "conv2"),
        lw.conv("conv3", "conv2.pool", 32),
        lw.fc("fc", "conv3", num_classes),
    ))


def _spatial_dims(in_hw) -> tuple:
    """Normalize a spatial-size spec: int -> square, (H, W) -> as given.

    Delegates to lowering.spatial_dims, which validates the spec
    explicitly (length, positivity) instead of failing downstream."""
    return lw.spatial_dims(in_hw)


def graph_from_layers(layers, channels: Dict[str, int],
                      in_ch: int) -> OpGraph:
    """Convert a legacy flat LoweredLayer tuple into the op-graph IR.

    ``channels`` maps layer name -> output channels (read off weights or
    a plan — the flat form never carried them).  pool_after becomes an
    explicit 2x2/2 max-pool node named ``<layer>.pool``.
    """
    nodes = [lw.input_node(in_ch)]
    prev = "input"
    for lyr in layers:
        d = channels[lyr.name]
        if lyr.kind == "conv":
            nodes.append(lw.conv(lyr.name, prev, d, kk=lyr.kk,
                                 relu=lyr.relu))
        elif lyr.kind == "fc":
            nodes.append(lw.fc(lyr.name, prev, d, relu=lyr.relu))
        else:
            raise ValueError(f"unknown lowered-layer kind: {lyr.kind!r}")
        prev = lyr.name
        if lyr.pool_after:
            nodes.append(lw.pool(f"{lyr.name}.pool", prev))
            prev = f"{lyr.name}.pool"
    return OpGraph(tuple(nodes))


def as_graph(lowering, params: Optional[dict] = None,
             plan=None) -> OpGraph:
    """Normalize any runnable lowering to the op-graph IR.

    OpGraphs pass through; legacy flat tuples need channel counts, read
    from ``params`` weight shapes (preferred) or a CnnPlan's per-layer
    ``d`` — the executor's compiled wrapper has a plan but no params.
    """
    if isinstance(lowering, OpGraph):
        return lowering
    layers = tuple(lowering)
    if params is not None:
        channels = {l.name: int(params[l.name].shape[1]) for l in layers}
    elif plan is not None:
        channels = {l.name: p.d for l, p in zip(layers, plan.layers)}
    else:
        raise ValueError("converting a legacy flat lowering needs params "
                         "or a plan to recover channel counts")
    first = layers[0]
    if first.kind != "conv":
        raise ValueError(
            f"legacy flat lowerings must start with a conv layer to "
            f"recover C_in (got {first.kind!r}) — build an OpGraph with "
            f"an explicit input node instead")
    if params is not None:
        in_ch = int(params[first.name].shape[0]) // (first.kk * first.kk)
    else:
        in_ch = next(p.k for p in plan.layers) // (first.kk * first.kk)
    return graph_from_layers(layers, channels, in_ch)


def lowered_gemms(params: dict, lowering=None, in_hw=16) -> List[LayerGemm]:
    """Analytic GEMM table (for the scheduler) of a lowered runnable CNN.

    Walks the lowering (op-graph or legacy flat tuple), tracking spatial
    size through strides and pools, validating every weight shape against
    the graph — the same (C, K, D) the executor will feed the kernel, so
    plans and execution agree.

    ``in_hw`` is the input spatial size: an int for square images or an
    (H, W) pair for rectangular ones (conv rows become H_out*W_out).
    """
    graph = as_graph(lowering or small_cnn_lowering(), params=params)
    return lw.graph_gemms(graph, in_hw, params=params)


# ---------------------------------------------------------------------------
# Runnable small CNN for the accuracy (Table 4) experiments
# ---------------------------------------------------------------------------
def build_small_cnn(key: jax.Array, num_classes: int = 10,
                    in_hw: int = 16, in_ch: int = 3) -> dict:
    """A small conv net (3 conv + 1 fc) with explicit im2col GEMM layers."""
    k1, k2, k3, k4 = jax.random.split(key, 4)

    def glorot(k, shape):
        fan_in = shape[0]
        return jax.random.normal(k, shape, jnp.float32) / jnp.sqrt(fan_in)

    return {
        "conv1": glorot(k1, (in_ch * 9, 16)),
        "conv2": glorot(k2, (16 * 9, 32)),
        "conv3": glorot(k3, (32 * 9, 32)),
        "fc": glorot(k4, ((in_hw // 4) ** 2 * 32, num_classes)),
    }


def _im2col(x: jnp.ndarray, kk: int = 3) -> jnp.ndarray:
    """NHWC -> (N, H*W, C*kk*kk) patches with SAME padding (stride 1).

    Legacy shim over lowering.im2col (which also handles stride/padding
    and returns the output extent)."""
    cols, _ = lw.im2col(x, kk, kk, stride=1, padding="same")
    return cols


def lowered_apply(params: dict, x: jnp.ndarray, lowering=None,
                  matmul: Optional[Callable] = None) -> jnp.ndarray:
    """Forward pass of ANY lowered runnable CNN, driven by its lowering.

    The single source of truth for what a lowering computes — op-graph
    IR or legacy flat tuple: the executor (repro.exec.executor) replays
    exactly this structure through the Pallas kernel, and the
    bit-exactness oracle (exec.executor.reference_forward) calls this
    with the *same* lowering the executor ran — so the contract covers
    every lowered network (stride/depthwise/residual/pool included), not
    just the small CNN.

    ``matmul(a, w)`` defaults to exact and can be the photonic simulation
    (ops.photonic_matmul partial).  Rectangular images are first-class.
    """
    graph = as_graph(lowering or small_cnn_lowering(), params=params)
    return lw.graph_apply(params, x, graph, matmul)


def small_cnn_apply(params: dict, x: jnp.ndarray,
                    matmul: Optional[Callable] = None) -> jnp.ndarray:
    """Forward pass of the small CNN; delegates to ``lowered_apply`` with
    its own lowering so forward and lowering cannot drift."""
    return lowered_apply(params, x, small_cnn_lowering(), matmul)

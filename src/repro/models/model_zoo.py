"""Uniform model API over all assigned architectures.

Dispatches on ``cfg.family``:  'audio' -> encdec (Whisper), everything else
-> transformer.  Exposes exactly what the launcher needs:

    init_params / param_specs
    loss_fn(params, batch, cfg, ...)          -- next-token CE (train_4k)
    prefill_fn / decode_fn                    -- serving (prefill_*/decode_*)
    input_specs(cfg, shape)                   -- ShapeDtypeStruct stand-ins
    init_caches(cfg, batch, max_len)

Batches are dicts: {"tokens", "targets"} (+ "frames" for audio, "patches"
for vlm) — the modality frontends are stubs per the assignment, so frames /
patches arrive as precomputed embeddings.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, RunShape
from repro.models import encdec, transformer
from repro.models import layers as L
from repro.models import moe as M

WHISPER_FRAME_FEAT = 80   # log-mel bins fed to the (stubbed) conv frontend


def init_params(cfg: ArchConfig, key: Optional[jax.Array],
                abstract: bool = False) -> dict:
    if cfg.family == "audio":
        return encdec.init_params(cfg, key, abstract)
    return transformer.init_params(cfg, key, abstract)


def param_specs(cfg: ArchConfig) -> dict:
    if cfg.family == "audio":
        return encdec.param_specs(cfg)
    return transformer.param_specs(cfg)


def _xent(logits: jnp.ndarray, targets: jnp.ndarray) -> jnp.ndarray:
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def loss_fn(params: dict, batch: Dict[str, jnp.ndarray], cfg: ArchConfig,
            ctx: L.PhotonicCtx = L.EXACT_CTX, dist: M.DistCtx = M.LOCAL,
            remat: bool = True, ssm_impl: str = "jax",
            mtp_weight: float = 0.0) -> jnp.ndarray:
    """Next-token CE (+ optional DeepSeek-V3 MTP auxiliary loss).

    ``mtp_weight`` > 0 requires cfg.mtp_depth > 0; the MTP head is an
    auxiliary training feature and is OFF in the dry-run/roofline cells
    (the assigned shapes lower the primary train_step).
    """
    if cfg.family == "audio":
        logits = encdec.forward(params, batch["tokens"], batch["frames"],
                                cfg, ctx)
        return _xent(logits, batch["targets"])
    from repro.parallel import sharded_ce
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    use_sharded = sharded_ce.supports(cfg.vocab_size, dist)
    # §Perf iteration 1: vocab-sharded CE — the (B,S,V) logits tensor
    # never materializes replicated (see parallel/sharded_ce.py).
    hidden = transformer.forward(
        params, batch["tokens"], cfg, ctx, dist, remat=remat,
        ssm_impl=ssm_impl, prefix_embeds=batch.get("patches"),
        return_hidden=True)

    def ce(h, targets):
        if use_sharded:
            return sharded_ce.sharded_xent(head["table"], h, targets, dist)
        return _xent(h @ head["table"].T, targets)

    loss = ce(hidden, batch["targets"])
    if mtp_weight > 0.0 and cfg.mtp_depth > 0:
        h_mtp = transformer.mtp_hidden(params, hidden, batch["tokens"], cfg,
                                       ctx, dist)
        loss = loss + mtp_weight * ce(h_mtp, batch["targets"][:, 1:])
    return loss


def init_caches(cfg: ArchConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16) -> dict:
    if cfg.family == "audio":
        return encdec.init_caches(cfg, batch, max_len, dtype)
    return transformer.init_caches(cfg, batch, max_len, dtype)


def prefill_fn(params, batch, cfg: ArchConfig, caches,
               ctx: L.PhotonicCtx = L.EXACT_CTX,
               dist: M.DistCtx = M.LOCAL, ssm_impl: str = "jax"):
    if cfg.family == "audio":
        logits, caches, enc_out = encdec.prefill(
            params, batch["tokens"], batch["frames"], cfg, caches, ctx)
        return logits, {"layers": caches, "enc_out": enc_out}
    logits, caches = transformer.prefill(
        params, batch["tokens"], cfg, caches, ctx, dist, ssm_impl,
        prefix_embeds=batch.get("patches"))
    return logits, {"layers": caches}


def decode_fn(params, token, index, cfg: ArchConfig, state,
              ctx: L.PhotonicCtx = L.EXACT_CTX, dist: M.DistCtx = M.LOCAL):
    if cfg.family == "audio":
        logits, caches = encdec.decode_step(params, token, index,
                                            state["enc_out"], cfg,
                                            state["layers"], ctx)
        return logits, {**state, "layers": caches}
    logits, caches = transformer.decode_step(params, token, index, cfg,
                                             state["layers"], ctx, dist)
    return logits, {**state, "layers": caches}


# ---------------------------------------------------------------------------
# ShapeDtypeStruct stand-ins (dry-run input contract, deliverable e/f)
# ---------------------------------------------------------------------------
def input_specs(cfg: ArchConfig, shape: RunShape) -> Dict[str, object]:
    """Stand-ins for every model input of the step lowered for ``shape``.

    train/prefill: full-sequence batch.  decode: one new token + the decode
    state index (the KV cache itself is threaded as a donated argument whose
    specs come from ``cache_specs``).
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if shape.kind == "train":
            specs["targets"] = jax.ShapeDtypeStruct((b, s), i32)
        if cfg.family == "audio":
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, WHISPER_FRAME_FEAT),
                jnp.dtype(cfg.dtype))
        if cfg.family == "vlm":
            specs["patches"] = jax.ShapeDtypeStruct(
                (b, cfg.num_image_tokens, cfg.vision_embed_dim),
                jnp.dtype(cfg.dtype))
        return specs
    # decode: one token against a cache of length shape.seq_len
    return {"token": jax.ShapeDtypeStruct((b, 1), i32),
            "index": jax.ShapeDtypeStruct((), i32)}


def cache_specs(cfg: ArchConfig, shape: RunShape) -> dict:
    """Abstract cache pytree for decode cells (no allocation)."""
    caches = jax.eval_shape(
        lambda: init_caches(cfg, shape.global_batch, shape.seq_len,
                            jnp.dtype(cfg.dtype)))
    state = {"layers": caches}
    if cfg.family == "audio":
        state["enc_out"] = jax.ShapeDtypeStruct(
            (shape.global_batch, cfg.encoder_seq, cfg.d_model),
            jnp.dtype(cfg.dtype))
    return state

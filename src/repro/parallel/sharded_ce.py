"""Vocab-sharded cross-entropy (§Perf iteration 1).

The naive CE path materializes (B, S, V) logits replicated over the model
axis (40 GB f32 per device for qwen2-0.5b at train_4k) and pays the
all-gather that un-shards the vocab-parallel unembed matmul.  This module
keeps the logits vocab-sharded end to end — the BPCA insight restated at
datacenter scale: accumulate partial results locally, convert (reduce)
once per output.

shard_map over (data..., model):
  * each model shard computes its (B_loc, S, V/|model|) logit slice,
  * logsumexp runs locally with a pmax-stabilized exponent, psum over the
    model axis combines the partition functions,
  * the target logit is picked locally by shards that own the target id
    and psum'd (exactly one shard contributes per token),
  * the returned per-token loss is (B, S) batch-sharded; the caller means
    it.  All collectives are O(B*S) — V/|model| never crosses a link.

Differentiable: the only non-local ops are psum (linear) and a
stop-gradient pmax, so the VJP stays local + psum.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.models.moe import DistCtx


def _local_loss(table, hidden, targets, *, model_axis: str, vocab: int):
    """Per-shard body.  table: (V_loc, D); hidden: (B_loc, S, D)."""
    n_shards = jax.lax.axis_size(model_axis)
    my = jax.lax.axis_index(model_axis)
    v_loc = table.shape[0]
    logits = jnp.einsum("bsd,vd->bsv", hidden.astype(jnp.float32),
                        table.astype(jnp.float32))          # (B,S,V_loc)
    # numerically-stable sharded logsumexp
    local_max = jax.lax.stop_gradient(jnp.max(logits, axis=-1))
    gmax = jax.lax.pmax(local_max, model_axis)  # constant wrt grads
    sumexp = jnp.sum(jnp.exp(logits - gmax[..., None]), axis=-1)
    lse = jnp.log(jax.lax.psum(sumexp, model_axis)) + gmax   # (B,S)
    # target-logit pick: only the owning shard contributes
    local_ids = targets - my * v_loc
    in_range = (local_ids >= 0) & (local_ids < v_loc)
    picked = jnp.take_along_axis(
        logits, jnp.clip(local_ids, 0, v_loc - 1)[..., None], axis=-1)[..., 0]
    tgt = jax.lax.psum(jnp.where(in_range, picked, 0.0), model_axis)
    del n_shards, vocab
    return lse - tgt                                          # (B,S)


def sharded_xent(head_table: jnp.ndarray, hidden: jnp.ndarray,
                 targets: jnp.ndarray, dist: DistCtx) -> jnp.ndarray:
    """Mean next-token CE with vocab-sharded logits.

    head_table: (V, D) sharded P('model', None); hidden: (B, S, D) batch-
    sharded; targets: (B, S).  Requires V % |model| == 0 — callers fall
    back to the naive path otherwise (e.g. whisper's 51865 vocab).
    """
    mesh = dist.mesh
    dspec = P(dist.data_axes)
    vocab = head_table.shape[0]
    per_token = shard_map(
        lambda t, h, y: _local_loss(t, h, y, model_axis=dist.model_axis,
                                    vocab=vocab),
        mesh=mesh,
        in_specs=(P(dist.model_axis, None), P(*dspec, None, None),
                  P(*dspec, None)),
        out_specs=P(*dspec, None),
        check_rep=False,
    )(head_table, hidden, targets)
    return jnp.mean(per_token)


def supports(vocab: int, dist: DistCtx) -> bool:
    return (dist.mesh is not None and
            vocab % dist.mesh.shape[dist.model_axis] == 0)

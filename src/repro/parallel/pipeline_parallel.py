"""Pipeline parallelism (optional extra, off the 40-cell baseline path).

GPipe-style microbatch pipelining over a mesh axis using shard_map +
collective_permute (ppermute): stage s holds layer slice s and forwards its
activation to stage s+1 every tick.  M microbatches finish in M + S - 1
ticks; bubble fraction = (S-1)/(S+M-1).

The whole schedule is a single jitted lax.scan — no host control flow, the
TPU-idiomatic form of a pipeline schedule.  Forward pass (microbatched
inference/eval); a training variant wraps this in jax.grad unchanged.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_stages + n_microbatches - 1)


def pipeline_forward(block_fn: Callable, stage_params, x: jnp.ndarray,
                     mesh: Mesh, axis: str = "stage") -> jnp.ndarray:
    """Run x through S pipeline stages with microbatching.

    block_fn(stage_param_slice, mb) -> mb : one stage's computation.
    stage_params: leaves with leading dim S, sharded P(axis, ...).
    x: (M, mb, features...) microbatches (replicated; stage 0 injects
    them in order).  Returns (M, mb, features...) outputs.
    """
    n_stages = mesh.shape[axis]
    m_total = x.shape[0]
    ticks = m_total + n_stages - 1

    def body(params, xs):
        stage = jax.lax.axis_index(axis)
        p = jax.tree.map(lambda a: a[0], params)
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def tick(state, t):
            carry, outputs = state
            inject = jnp.where(
                stage == 0,
                jax.lax.dynamic_index_in_dim(
                    xs, jnp.clip(t, 0, m_total - 1), 0, keepdims=False),
                carry)
            y = block_fn(p, inject)
            done = t - (n_stages - 1)
            outputs = jax.lax.cond(
                (stage == n_stages - 1) & (done >= 0),
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.clip(done, 0, m_total - 1), 0),
                lambda o: o, outputs)
            carry = jax.lax.ppermute(y, axis, perm)
            return (carry, outputs), None

        init = (jnp.zeros(xs.shape[1:], xs.dtype), jnp.zeros_like(xs))
        (_, outputs), _ = jax.lax.scan(tick, init, jnp.arange(ticks))
        # only the last stage holds results; psum broadcasts them
        return jax.lax.psum(outputs, axis)

    pspec = jax.tree.map(lambda _: P(axis), stage_params,
                         is_leaf=lambda a: hasattr(a, "ndim"))
    return shard_map(body, mesh=mesh, in_specs=(pspec, P()), out_specs=P(),
                     check_rep=False)(stage_params, x)

"""Logical-axis sharding: map model-zoo param specs to mesh PartitionSpecs.

Every parameter in the zoo carries a tuple of *logical* axis names
(models/layers.py).  ``RULES`` maps logical -> mesh axes; a mesh axis is
used at most once per param (first logical occurrence wins — e.g. MoE
expert tensors (EXPERT, EMBED, MLP) shard EXPERT over 'model' and leave MLP
replicated, which is exactly what the shard_map EP path expects).

Batch/activation sharding: batch over the data axes ('pod' + 'data' on the
multi-pod mesh).  Decode caches with global_batch < |data| switch to
sequence sharding (SP) so the long_500k cells spread their KV cache instead
of replicating it.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> preferred mesh axis (tuples = shard over several axes;
# trailing members are dropped if the dim doesn't divide their product)
RULES: Dict[Optional[str], object] = {
    "embed": None,
    "mlp": "model",
    "heads": "model",
    "kv_heads": "model",
    "vocab": "model",
    # FSDP for MoE giants: 256 experts shard over model*data = 256 chips
    # persistently (deepseek-v3 bf16 experts: 84 GB -> 5 GB per device);
    # the shard_map EP entry (in_spec P('model')) re-gathers one layer's
    # local experts over 'data' at use — exactly the FSDP gather, inserted
    # by XLA automatically.
    "expert": ("model", "data"),
    "ssm_inner": "model",
    "stack": None,
    None: None,
}


# Full FSDP for the MoE giants (deepseek v2/v3): every large weight class
# shards over model*data = 256 chips persistently; XLA inserts the
# per-layer all-gather at use.  Other archs keep pure TP (they already fit,
# and FSDP costs collectives).
FSDP_RULES: Dict[Optional[str], object] = {
    **RULES,
    "mlp": ("model", "data"),
    "heads": ("model", "data"),
    "kv_heads": ("model", "data"),
    "vocab": ("model", "data"),
}


def spec_to_pspec(axes: Tuple, rules: Optional[dict] = None) -> P:
    rules = rules or RULES
    used = set()
    out = []
    for a in axes:
        mesh_axis = rules.get(a)
        if isinstance(mesh_axis, tuple):
            fresh = tuple(m for m in mesh_axis if m not in used)
            used.update(fresh)
            out.append(fresh if fresh else None)
            continue
        if mesh_axis in used:
            mesh_axis = None
        if mesh_axis is not None:
            used.add(mesh_axis)
        out.append(mesh_axis)
    return P(*out)


def _divisible(shape: Tuple[int, ...], pspec: P, mesh: Mesh) -> P:
    """Drop sharding on dims the mesh doesn't divide evenly.  XLA tolerates
    uneven sharding, but padded shards waste memory and make cost analysis
    lie — replicating the stragglers is cheaper for the odd vocab sizes
    (whisper 51865, mamba2 50280)."""
    fixed = []
    for dim, ax in zip(shape, tuple(pspec) + (None,) * (len(shape) -
                                                        len(tuple(pspec)))):
        if ax is None:
            fixed.append(None)
            continue
        if isinstance(ax, tuple):
            # keep the longest prefix whose product divides the dim
            kept = []
            prod = 1
            for m in ax:
                if dim % (prod * mesh.shape[m]) == 0:
                    kept.append(m)
                    prod *= mesh.shape[m]
                else:
                    break
            fixed.append(tuple(kept) if kept else None)
            continue
        size = mesh.shape[ax] if isinstance(ax, str) else 1
        fixed.append(ax if dim % size == 0 else None)
    return P(*fixed)


def param_shardings(spec_tree, mesh: Mesh, shape_tree=None,
                    rules: Optional[dict] = None):
    """Spec tree (tuples of logical axes) -> tree of NamedSharding.

    ``shape_tree`` (abstract params) enables the divisibility fixup.
    """
    is_leaf = lambda x: isinstance(x, tuple)  # noqa: E731
    if shape_tree is None:
        return jax.tree.map(
            lambda axes: NamedSharding(mesh, spec_to_pspec(axes, rules)),
            spec_tree, is_leaf=is_leaf)
    return jax.tree.map(
        lambda axes, sds: NamedSharding(
            mesh, _divisible(sds.shape, spec_to_pspec(axes, rules), mesh)),
        spec_tree, shape_tree, is_leaf=is_leaf)


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_sharding(mesh: Mesh, ndim: int = 2) -> NamedSharding:
    """Token batches: (B, S, ...) with B over the data axes."""
    return NamedSharding(mesh, P(data_axes(mesh), *([None] * (ndim - 1))))


def batch_pspec(mesh: Mesh, example) -> NamedSharding:
    return NamedSharding(mesh, P(data_axes(mesh),
                                 *([None] * (example.ndim - 1))))


def cache_shardings(cache_tree, mesh: Mesh, global_batch: int):
    """Decode-state sharding.

    Leaves are (B, S, heads, hd) / (B, S, R) / (B, heads, P, S) / (B, S)
    shaped.  Rule: shard B over data when divisible; otherwise (long_500k,
    B=1) shard the *sequence/slots* dim over data (SP).  Head-like dims go
    over 'model' when divisible.
    """
    daxes = data_axes(mesh)
    dsize = 1
    for a in daxes:
        dsize *= mesh.shape[a]
    msize = mesh.shape.get("model", 1)

    def leaf(sds):
        shape = sds.shape
        spec = [None] * len(shape)
        batched = shape[0] % dsize == 0
        if batched:
            spec[0] = daxes
        elif len(shape) >= 2 and shape[1] >= 1024 and \
                shape[1] % (dsize * msize) == 0:
            spec[1] = daxes + ("model",)         # B=1: slots over ALL axes
        elif len(shape) >= 2 and shape[1] % dsize == 0:
            spec[1] = daxes                      # sequence-sharded cache (SP)
        if len(shape) == 4:
            if shape[1] >= 1024:                 # (B, slots, KVH, HD)
                # §Perf iteration 3: sequence-shard the decode cache
                # (flash-decode): batch-divisible cells put slots over
                # 'model'; B=1 long-context cells put slots over ALL axes
                # (matches attention.decode_axes).  Falls back to head/dim
                # sharding if slots don't divide.
                if batched and shape[1] % msize == 0:
                    spec[1] = "model"
                elif not batched and shape[1] % (dsize * msize) == 0:
                    spec[1] = daxes + ("model",)
                elif shape[2] % msize == 0 and shape[2] >= msize:
                    spec[2] = "model"
                elif shape[3] % msize == 0:
                    spec[3] = "model"
            else:                                # (B, H, P, S) ssm state
                if spec[1] is None and shape[1] % msize == 0:
                    spec[1] = "model"
        elif len(shape) == 3:
            if shape[1] >= 1024 and shape[1] % msize == 0 and \
                    spec[1] is None:
                spec[1] = "model"                # MLA compressed cache slots
            elif shape[2] % msize == 0 and shape[2] >= 512:
                spec[2] = "model"                # conv state channels
        elif len(shape) == 2 and shape[1] >= 1024 and \
                shape[1] % msize == 0 and spec[1] is None:
            spec[1] = "model"                    # cache 'pos' metadata
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(leaf, cache_tree)


def zero1_shardings(spec_tree, mesh: Mesh, shape_tree,
                    rules: Optional[dict] = None):
    """ZeRO-1 optimizer-moment sharding: params' PartitionSpec plus the
    data axes on the largest still-unsharded divisible dim.

    Moments are 8/10 of training-state bytes; sharding them over 'data'
    (x16 here) is what lets deepseek-v3-671b's optimizer state fit a 16 GB
    v5e chip (EXPERIMENTS.md §Dry-run).  The update gathers nothing: AdamW
    is elementwise, so each shard updates its moment slice against its
    (grad, param) slice — XLA inserts the reduce-scatter automatically.
    """
    daxes = data_axes(mesh)
    dsize = 1
    for a in daxes:
        dsize *= mesh.shape[a]
    is_leaf = lambda x: isinstance(x, tuple)  # noqa: E731

    def leaf(axes, sds):
        base = tuple(_divisible(sds.shape, spec_to_pspec(axes, rules), mesh))
        base = base + (None,) * (len(sds.shape) - len(base))
        used = set()
        for ax in base:
            used.update(ax if isinstance(ax, tuple) else (ax,))
        # shard over whatever data axes the param spec left unused (on the
        # multi-pod mesh FSDP'd experts still have 'pod' available)
        avail = tuple(a for a in daxes if a not in used)
        if not avail:
            return NamedSharding(mesh, P(*base))
        asize = 1
        for a in avail:
            asize *= mesh.shape[a]
        best, best_dim = None, 0
        for i, (dim, ax) in enumerate(zip(sds.shape, base)):
            if ax is None and dim % asize == 0 and dim > best_dim:
                best, best_dim = i, dim
        if best is not None:
            base = base[:best] + (avail,) + base[best + 1:]
        return NamedSharding(mesh, P(*base))

    return jax.tree.map(leaf, spec_tree, shape_tree, is_leaf=is_leaf)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())

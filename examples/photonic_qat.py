"""Beyond-paper feature: photonic-aware QAT.

Trains the same tiny LM twice — exact numerics vs *through* the HEANA
simulation (STE gradients, detection noise on) — then evaluates both under
HEANA inference numerics.

Honest finding (EXPERIMENTS.md §Numerics extras): at smoke scale this is a
NULL RESULT — straight-through gradients make the two runs near-identical,
so the script demonstrates the *mechanism* (trainability through the
photonic simulation for every arch family), not a measured QAT win.

  PYTHONPATH=src python examples/photonic_qat.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.photonic_gemm import design_point
from repro.core.types import Backend
from repro.data.pipeline import DataConfig, make_source
from repro.models import model_zoo as zoo
from repro.models.layers import PhotonicCtx
from repro.optim import optimizer as opt

STEPS, BATCH, SEQ = 200, 8, 64


def run(train_ctx: PhotonicCtx, eval_ctx: PhotonicCtx, seed=0):
    cfg = get_config("qwen2-0.5b", smoke=True)
    adam = opt.AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=STEPS)
    params = zoo.init_params(cfg, jax.random.PRNGKey(seed))
    state = opt.init(params)
    data = make_source(DataConfig(vocab_size=cfg.vocab_size, seq_len=SEQ,
                                  global_batch=BATCH, seed=seed))

    @jax.jit
    def step(params, state, tokens, targets, key):
        ctx = PhotonicCtx(cfg=train_ctx.cfg, key=key, impl="ref") \
            if train_ctx.cfg else train_ctx

        def loss_fn(p):
            return zoo.loss_fn(p, {"tokens": tokens, "targets": targets},
                               cfg, ctx=ctx)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, state, _ = opt.apply(adam, params, state, grads)
        return params, state, loss

    for s in range(STEPS):
        b = data.batch(s)
        params, state, loss = step(params, state, jnp.asarray(b["tokens"]),
                                   jnp.asarray(b["targets"]),
                                   jax.random.PRNGKey(1000 + s))
    # eval under photonic inference numerics
    eval_losses = []
    for s in range(5):
        b = data.batch(10_000 + s)
        eval_losses.append(float(zoo.loss_fn(
            params, {"tokens": jnp.asarray(b["tokens"]),
                     "targets": jnp.asarray(b["targets"])}, cfg,
            ctx=eval_ctx)))
    return float(loss), sum(eval_losses) / len(eval_losses)


def main():
    heana = design_point(Backend.HEANA, bits=4, data_rate_gsps=1.0,
                         adc_bits=8)
    eval_ctx = PhotonicCtx(cfg=heana, key=jax.random.PRNGKey(9), impl="ref")
    print("training EXACT, evaluating on HEANA numerics...")
    tr_loss_e, ev_e = run(PhotonicCtx(), eval_ctx)
    print(f"  train loss {tr_loss_e:.4f} -> HEANA eval loss {ev_e:.4f}")
    print("training THROUGH HEANA (QAT), evaluating on HEANA numerics...")
    tr_loss_q, ev_q = run(PhotonicCtx(cfg=heana, impl="ref"), eval_ctx)
    print(f"  train loss {tr_loss_q:.4f} -> HEANA eval loss {ev_q:.4f}")
    gap = ev_e - ev_q
    print(f"\nQAT advantage on photonic hardware: {gap:+.4f} nats "
          f"({'QAT better' if gap > 0 else 'exact better'})")


if __name__ == "__main__":
    main()

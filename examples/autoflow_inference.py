"""The execution engine end-to-end: plan, execute, report.

1. Auto-schedule per-layer dataflows for the paper's CNNs — on HEANA the
   plan keeps OS (or a free-latency WS swap on tiny layers); on the
   thermo-optic AMW baseline it mixes WS with IS for the fc layer.
2. Show the content-addressed plan cache: re-planning is all hits.
3. Execute a small CNN end-to-end through the Pallas TAOM kernel and
   check it against the pure-jnp reference bit-exactly (noise off), then
   run it with detection noise threaded per layer.

Run:  PYTHONPATH=src python examples/autoflow_inference.py
"""
import jax
import jax.numpy as jnp

from repro.core.perf_model import AcceleratorConfig, cnn_inference
from repro.core.types import Backend, Dataflow, PhotonicConfig
from repro.exec import (PlanCache, execute_cnn, plan_for_network, plan_table,
                        reference_forward, schedule_cnn)
from repro.models.cnn import CNN_ZOO, build_small_cnn


def main():
    # 1 — per-layer dataflow auto-scheduling
    cache = PlanCache()
    print("== auto-scheduled dataflow mix (batch 1, 1 GS/s) ==")
    for be in ("heana", "amw"):
        acc = AcceleratorConfig.equal_area(be, Dataflow.OS, 1.0)
        for name, fn in CNN_ZOO.items():
            layers = fn()
            plan = schedule_cnn(layers, acc, batch=1, cache=cache)
            best_fixed = max(cnn_inference(
                layers, AcceleratorConfig.equal_area(be, f, 1.0)).fps
                for f in Dataflow)
            mix = plan.mix()
            print(f"  {be:6s} {name:14s} mix os/is/ws = "
                  f"{mix['os']}/{mix['is']}/{mix['ws']}   "
                  f"auto {plan.fps:12.1f} FPS  (best fixed "
                  f"{best_fixed:12.1f}, x{plan.fps / best_fixed:.3f})")

    # 2 — the plan cache makes re-planning free
    plan = schedule_cnn(CNN_ZOO["googlenet"](),
                        AcceleratorConfig.equal_area("heana", Dataflow.OS,
                                                     1.0),
                        batch=1, cache=cache)
    print(f"\n== re-plan googlenet: {plan.cache_hits} hits / "
          f"{plan.cache_misses} misses ({len(cache)} cached plans) ==")
    print("\n== googlenet plan, heaviest layers ==")
    print(plan_table(plan, max_rows=5))

    # 3 — end-to-end execution through the Pallas kernel
    key = jax.random.PRNGKey(0)
    params = build_small_cnn(key)
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 16, 16, 3))
    acc = AcceleratorConfig.equal_area("heana", Dataflow.OS, 1.0)
    exec_plan = plan_for_network(params, acc, batch=4, cache=cache)

    cfg = PhotonicConfig(backend=Backend.HEANA, bits=6, dpe_size=83,
                         noise_enabled=False)
    res = execute_cnn(params, x, exec_plan, cfg, impl="pallas")
    ref = reference_forward(params, x, cfg)
    print(f"\n== executed small CNN (Pallas) vs jnp reference: bit-exact = "
          f"{bool(jnp.all(res.logits == ref))} ==")
    print(f"   modeled: {exec_plan.fps:.0f} FPS, "
          f"{exec_plan.latency_s * 1e9:.2f} ns/batch; per-layer flows: "
          f"{[t.dataflow for t in res.traces]}")

    cfg_noisy = PhotonicConfig(backend=Backend.HEANA, bits=6, dpe_size=83,
                               noise_enabled=True)
    noisy = execute_cnn(params, x, exec_plan, cfg_noisy,
                        key=jax.random.PRNGKey(7), impl="pallas")
    drift = float(jnp.linalg.norm(noisy.logits - res.logits) /
                  jnp.linalg.norm(res.logits))
    print(f"   with detection noise (per-layer keys): rel logit drift "
          f"{drift:.4f}")


if __name__ == "__main__":
    main()

"""Serving example: batched prefill + greedy decode on any arch.

  PYTHONPATH=src python examples/serve_lm.py --arch zamba2-7b --smoke
"""
import argparse

from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    r = serve(args.arch, args.smoke, args.batch, args.prompt_len, args.gen)
    print(f"arch={args.arch} prefill={r.prefill_s*1e3:.1f}ms "
          f"decode={r.decode_s*1e3:.1f}ms throughput={r.tokens_per_s:.1f} "
          f"tok/s")
    print("first sequence:", r.tokens[0].tolist())


if __name__ == "__main__":
    main()

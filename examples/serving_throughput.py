"""The compiled serving path: plan once, compile once, stream batches.

Demonstrates the executor hot-path fix (ISSUE 2):

1. Plan the small CNN once (content-addressed plan cache).
2. ``compiled_forward`` returns a jit executable with the plan's tilings
   baked in as static args — the first call compiles, every later call
   runs the cached executable: zero retraces, zero per-layer host syncs.
3. Stream a few warm batches and measure sustained images/sec, compiled
   vs the eager op-by-op path the executor used to be.
4. Traces (per-layer numerics fingerprints) are computed on-device and
   materialize lazily — only when actually read, after the stream.

Run:  PYTHONPATH=src python examples/serving_throughput.py
"""
import time

import jax

from repro.core.perf_model import AcceleratorConfig
from repro.core.types import Backend, Dataflow, PhotonicConfig
from repro.exec import (PlanCache, compiled_forward, execute_cnn,
                        plan_for_network, trace_count)
from repro.models.cnn import build_small_cnn

BATCH = 32
STREAM = 8


def main():
    key = jax.random.PRNGKey(0)
    params = build_small_cnn(key)
    acc = AcceleratorConfig.equal_area("heana", Dataflow.OS, 1.0)
    cfg = PhotonicConfig(backend=Backend.HEANA, bits=6, dpe_size=83,
                         noise_enabled=False)

    # 1 — plan once
    plan = plan_for_network(params, acc, batch=BATCH, cache=PlanCache())
    print(f"== plan: batch {BATCH}, flows "
          f"{[p.dataflow.value for p in plan.layers]}, tiles "
          f"{[(p.tile.block_m, p.tile.block_d) for p in plan.layers]} ==")

    # 2 — compile once (cold call traces + compiles)
    fn = compiled_forward(plan, cfg)
    x0 = jax.random.normal(jax.random.fold_in(key, 1),
                           (BATCH, 16, 16, 3))
    t0 = time.perf_counter()
    fn(params, x0, None)[0].block_until_ready()
    print(f"== cold call (trace + compile): "
          f"{time.perf_counter() - t0:.2f} s ==")

    # 3 — stream warm batches
    traces_before = trace_count()
    t0 = time.perf_counter()
    last = None
    for i in range(STREAM):
        x = jax.random.normal(jax.random.fold_in(key, 100 + i),
                              (BATCH, 16, 16, 3))
        last = execute_cnn(params, x, plan, cfg)  # compiled by default
    last.block_until_ready()
    dt = time.perf_counter() - t0
    ips = STREAM * BATCH / dt
    print(f"== streamed {STREAM} warm batches: {ips:,.0f} images/s "
          f"(host sim), retraces during stream: "
          f"{trace_count() - traces_before} ==")

    # eager baseline (the pre-fix behavior), one batch
    t0 = time.perf_counter()
    execute_cnn(params, x0, plan, cfg, compiled=False).block_until_ready()
    eager_s = time.perf_counter() - t0
    print(f"== eager baseline: {BATCH / eager_s:,.0f} images/s "
          f"-> compiled speedup {ips * eager_s / BATCH:,.0f}x ==")

    # 4 — traces materialize lazily, only now
    print("\n== per-layer trace of the last batch (lazy fingerprints) ==")
    for t in last.traces:
        print(f"   {t.name:6s} m={t.m:<6d} k={t.k:<4d} d={t.d:<4d} "
              f"{t.dataflow} tile=({t.block_m},{t.block_d}) "
              f"mean|out|={t.out_mean_abs:.4f}")
    print(f"\n   modeled (photonic perf model): {plan.fps:,.0f} FPS — "
          f"different machine, never compare to host img/s directly")


if __name__ == "__main__":
    main()

"""The paper's own workload: CNN inference ON the simulated HEANA.

Trains a small CNN on a synthetic 10-class task, then runs its inference
with every conv/fc GEMM executed by the photonic simulation at the 8-bit
design point — HEANA (BPCA analog carry) vs MAW (per-chunk ADC) vs ideal
int8 — and reports the Table-4-style accuracy drops, plus the perf model's
FPS/FPS-per-W for the same accelerators on the paper's four CNNs.

  PYTHONPATH=src python examples/heana_cnn_inference.py
"""
from benchmarks.table4_accuracy import evaluate, train_model
from repro.core.perf_model import AcceleratorConfig, cnn_inference, gmean
from repro.core.types import Dataflow
from repro.models.cnn import CNN_ZOO


def main():
    print("training reference CNN (exact numerics)...")
    params = train_model()
    accs = {m: evaluate(params, m) for m in ("exact", "int8", "heana",
                                             "maw")}
    print("\n== Table-4 proxy: top-1 under analog numerics ==")
    for m, a in accs.items():
        drop = 100 * (accs["exact"] - a)
        print(f"  {m:6s}: top-1 {a:.4f}   drop {drop:+.2f}%")

    print("\n== Fig-11 headline: HEANA-OS vs best baseline (gmean, 4 CNNs,"
          " 1 GS/s) ==")
    ratios_fps, ratios_w = {"amw": [], "maw": []}, {"amw": [], "maw": []}
    for name, fn in CNN_ZOO.items():
        layers = fn()
        h = cnn_inference(layers,
                          AcceleratorConfig.equal_area("heana", Dataflow.OS,
                                                       1.0))
        for base in ("amw", "maw"):
            bf = max(cnn_inference(layers, AcceleratorConfig.equal_area(
                base, f, 1.0)).fps for f in Dataflow)
            bw = max(cnn_inference(layers, AcceleratorConfig.equal_area(
                base, f, 1.0)).fps_per_watt for f in Dataflow)
            ratios_fps[base].append(h.fps / bf)
            ratios_w[base].append(h.fps_per_watt / bw)
    for base in ("amw", "maw"):
        print(f"  vs {base}: {gmean(ratios_fps[base]):6.1f}x FPS   "
              f"{gmean(ratios_w[base]):5.1f}x FPS/W   "
              f"(paper: >=66x / >=84x)")


if __name__ == "__main__":
    main()

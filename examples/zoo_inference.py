"""Executable model zoo demo: the paper's four evaluation CNNs as
reduced-scale runnable graphs, planned and executed end-to-end.

For each network: build params from the graph, auto-schedule dataflows
and tilings, run the compiled Pallas path, and verify the output is
bit-exact against the pure-jnp oracle with zero warm-call retraces.

``--smoke`` (the CI zoo-smoke gate) runs one ResNet + one MobileNet
variant and exits non-zero on any conformance violation — the graph
execution path cannot silently rot.

Run:  PYTHONPATH=src python examples/zoo_inference.py [--smoke]
"""
import argparse
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import perf_model as pm
from repro.core.types import Backend, Dataflow, PhotonicConfig
from repro.exec import (PlanCache, execute_cnn, graph_summary,
                        plan_for_network, plan_table, reference_forward,
                        trace_count)
from repro.models.zoo_cnn import PAPER_ZOO

HEANA = pm.AcceleratorConfig.equal_area("heana", Dataflow.OS, 1.0)


def run_model(model, batch=2, seed=0, verbose=True) -> bool:
    cfg = PhotonicConfig(backend=Backend.HEANA, bits=6, dpe_size=83,
                         noise_enabled=False)
    params = model.init_params(jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1),
                          (batch, *model.in_hw, model.in_ch))
    plan = plan_for_network(params, HEANA, batch=batch, in_hw=model.in_hw,
                            lowering=model.graph, cache=PlanCache())
    res = execute_cnn(params, x, plan, cfg, impl="pallas",
                      lowering=model.graph).block_until_ready()
    ref = reference_forward(params, x, cfg, lowering=model.graph)
    exact = bool(jnp.all(res.logits == ref))
    before = trace_count()
    execute_cnn(params, x, plan, cfg, impl="pallas", lowering=model.graph)
    no_retrace = trace_count() == before

    s = graph_summary(model.graph, model.name)
    if verbose:
        print(f"\n## {model.name}  ({s['n_nodes']} nodes, "
              f"{s['n_gemm_layers']} GEMM layers, ops={s['ops']})")
        print(f"   modeled fps={plan.fps:.1f}  mix={plan.mix()}  "
              f"logits={tuple(res.logits.shape)}")
        print(f"   bit-exact vs oracle: {exact}   "
              f"zero warm retraces: {no_retrace}")
        print(plan_table(plan, max_rows=6))
    if not exact:
        print(f"FAIL {model.name}: compiled output != oracle",
              file=sys.stderr)
    if not no_retrace:
        print(f"FAIL {model.name}: warm call retraced", file=sys.stderr)
    return exact and no_retrace


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: one ResNet + one MobileNet only")
    args = ap.parse_args()
    names = (["resnet_mini", "mobilenet_mini"] if args.smoke
             else list(PAPER_ZOO))
    ok = all([run_model(PAPER_ZOO[n], verbose=not args.smoke)
              for n in names])
    if not ok:
        sys.exit(1)
    print(f"\nzoo {'smoke ' if args.smoke else ''}conformance: "
          f"{len(names)}/{len(names)} networks bit-exact, no retraces")


if __name__ == "__main__":
    main()

"""One operating point, everything derived: the ISSUE 5 fan-out demo.

A single ``core.hw.OperatingPoint`` — (backend, dataflow, bits, data
rate) — is the only hardware knob you set.  Everything else follows from
the paper's own solvers:

  * DPE size N        <- scalability analysis (Eqs. 1-3, Fig. 9)
  * detection sigma   <- link budget + noise model (Eqs. 1-2)
  * per-event energy  <- Table 3 constants
  * kernel PhotonicConfig + scheduler AcceleratorConfig <- factories

The demo prints the derived physics for the three DPU organizations,
then executes a zoo network end-to-end at the HEANA equal-area point and
shows the executed-trace energy/FPS/W agreeing with the analytic
perf-model prediction — and a deliberately incoherent kernel config
being rejected by the executor.

Run:  PYTHONPATH=src python examples/operating_point.py
"""
import jax

from repro.core import hw
from repro.core import perf_model as pm
from repro.core.types import Dataflow
from repro.exec import PlanCache, execute_cnn, plan_for_network
from repro.models.zoo_cnn import ZOO


def main():
    print("## Derived operating points (B=4)\n")
    print("| backend | DR GS/s | N | DPUs | P_pd dBm | sigma_rel | ENOB |")
    print("|---|---|---|---|---|---|---|")
    for be in ("heana", "amw", "maw"):
        for dr in (1.0, 5.0, 10.0):
            d = hw.OperatingPoint.equal_area(be, Dataflow.OS,
                                             dr).describe()
            print(f"| {be} | {dr:g} | {d['dpe_size']} | {d['n_dpus']} | "
                  f"{d['pd_power_dbm']:.2f} | {d['noise_sigma_rel']:.4f} "
                  f"| {d['enob']:.2f} |")

    model = ZOO["resnet_mini"]
    op = hw.OperatingPoint.equal_area("heana", Dataflow.OS, 1.0,
                                      noise_enabled=False)
    params = model.init_params(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (2, *model.in_hw, model.in_ch))
    plan = plan_for_network(params, op, batch=2, in_hw=model.in_hw,
                            lowering=model.graph, cache=PlanCache())
    res = execute_cnn(params, x, plan, op.kernel_config(), impl="pallas",
                      lowering=model.graph).block_until_ready()
    te = res.energy()
    ana = pm.cnn_inference(model.gemms(params), plan.acc, batch=2,
                           dataflows=list(plan.dataflows))
    print(f"\n## {model.name} executed at the HEANA equal-area point\n")
    print(f"   executed-trace: fps={te.fps:.1f}  fps/W="
          f"{te.fps_per_watt:.1f}  uJ/img={te.j_per_image * 1e6:.3f}")
    print(f"   analytic model: fps={ana.fps:.1f}  fps/W="
          f"{ana.fps_per_watt:.1f}")
    print(f"   coherent by construction: rel gap = "
          f"{abs(te.fps_per_watt - ana.fps_per_watt) / ana.fps_per_watt:.1e}")
    top = max(res.traces, key=lambda t: t.executed_energy_j)
    print(f"   hottest layer: {top.name} "
          f"({top.executed_energy_j * 1e6:.2f} uJ, "
          f"{top.adc_conversions} ADC conversions, {top.dataflow})")

    print("\n## Incoherent kernel configs are rejected\n")
    try:
        execute_cnn(params, x, plan, op.kernel_config(bits=6),
                    impl="ref", lowering=model.graph)
    except ValueError as e:
        print("   " + str(e).splitlines()[0])
        print("   (full message names every disagreeing field and the "
              "OperatingPoint fix)")


if __name__ == "__main__":
    main()

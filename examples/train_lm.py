"""End-to-end driver (deliverable b): train a ~100M-param LM for a few
hundred steps on the synthetic pipeline, with checkpoint/restart.

Defaults train mamba2-130m (the smallest full config, ~168M params with
embeddings) for 200 steps at seq 256.  On CPU this takes a while; pass
--smoke to use the reduced config for a fast sanity run, or lower --steps.

  PYTHONPATH=src python examples/train_lm.py --steps 200
  PYTHONPATH=src python examples/train_lm.py --smoke --steps 50
"""
import argparse

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()
    res = train(arch=args.arch, smoke=args.smoke, steps=args.steps,
                batch=args.batch, seq=args.seq, lr=3e-4,
                ckpt_dir=args.ckpt_dir, ckpt_every=50, resume=True)
    print(f"\nloss {res.first_loss:.3f} -> {res.final_loss:.3f} over "
          f"{res.steps} steps ({res.tokens_per_s:.0f} tok/s); "
          f"checkpoints in {res.ckpt_dir}")
    assert res.final_loss < res.first_loss, "training must reduce loss"


if __name__ == "__main__":
    main()

"""The batched serving engine: buckets, warmup, micro-batching, stats.

Demonstrates exec.serving (ISSUE 4) end to end:

1. Build a ServingEngine for a zoo network: every power-of-two batch
   bucket gets its own auto-scheduled CnnPlan up front (shared plan
   cache), and ``warmup()`` pre-traces every executable — after it, no
   request ever pays a trace.
2. Serve mixed-size requests: each is padded to the smallest bucket that
   fits and sliced back (zero retraces, bitwise equal to an exact-size
   batch).
3. Coalesce single-image requests through the thread-safe MicroBatcher
   (Futures resolve with each request's row of the batched logits).
4. If several devices are visible (e.g. XLA_FLAGS=
   --xla_force_host_platform_device_count=4), serve the same traffic
   data-parallel: the bucketed batch is sharded over the batch axis with
   a NamedSharding and the logits are bitwise equal to single-device.
5. Print the serving metrics: p50/p99 latency, sustained throughput,
   padding overhead, cache stats.

Run:  PYTHONPATH=src python examples/serving_engine.py
"""
import time

import jax
import jax.numpy as jnp

from repro.core.perf_model import AcceleratorConfig
from repro.core.types import Backend, Dataflow, PhotonicConfig
from repro.exec import MicroBatcher, ServingEngine, trace_count
from repro.models.zoo_cnn import ZOO

NETWORK = "small_cnn"
MAX_BATCH = 8
REQUEST_SIZES = (1, 3, 5, 8, 2, 8, 4, 1)


def main():
    zoo = ZOO[NETWORK]
    key = jax.random.PRNGKey(0)
    params = zoo.init_params(key)
    acc = AcceleratorConfig.equal_area("heana", Dataflow.OS, 1.0)
    cfg = PhotonicConfig(backend=Backend.HEANA, bits=6, dpe_size=83,
                         noise_enabled=False)

    # 1 — bucketed plans + AOT warmup
    engine = ServingEngine(params, acc, cfg, lowering=zoo.graph,
                           in_hw=zoo.in_hw, max_batch=MAX_BATCH)
    cold = engine.warmup()
    print(f"== {NETWORK}: buckets {engine.buckets}, warmup "
          f"{ {b: round(s, 2) for b, s in cold.items()} } s ==")

    # 2 — mixed-size traffic, zero retraces
    h, w = zoo.in_hw
    traces0 = trace_count()
    t0 = time.perf_counter()
    for i, n in enumerate(REQUEST_SIZES):
        x = jax.random.normal(jax.random.fold_in(key, 100 + i),
                              (n, h, w, zoo.in_ch))
        logits = engine.infer(x)
        assert logits.shape == (n, zoo.num_classes)
    dt = time.perf_counter() - t0
    n_imgs = sum(REQUEST_SIZES)
    print(f"== served {len(REQUEST_SIZES)} mixed-size requests "
          f"({n_imgs} images) in {dt:.2f} s — retraces: "
          f"{trace_count() - traces0} ==")

    # 3 — micro-batched single-image traffic
    with MicroBatcher(engine, max_delay_s=0.01) as mb:
        futs = [mb.submit(jax.random.normal(
            jax.random.fold_in(key, 200 + i), (h, w, zoo.in_ch)))
            for i in range(12)]
        outs = [f.result(timeout=60) for f in futs]
    assert all(o.shape == (zoo.num_classes,) for o in outs)
    print(f"== micro-batcher coalesced 12 single-image requests: "
          f"{mb.stats()} ==")

    # 4 — data-parallel path (needs > 1 device)
    n_dev = len(jax.devices())
    if n_dev > 1 and MAX_BATCH % n_dev == 0:
        dp = ServingEngine(params, acc, cfg, lowering=zoo.graph,
                           in_hw=zoo.in_hw, max_batch=MAX_BATCH,
                           plan_cache=engine.plan_cache,
                           data_parallel=True)
        dp.warmup()
        x = jax.random.normal(jax.random.fold_in(key, 999),
                              (MAX_BATCH, h, w, zoo.in_ch))
        same = bool((jax.device_get(dp.infer(x)) ==
                     jax.device_get(engine.infer(x))).all())
        print(f"== data-parallel over {n_dev} devices: logits bitwise "
              f"equal to single-device = {same} ==")
    else:
        print(f"== data-parallel skipped ({n_dev} device(s); try "
              f"XLA_FLAGS=--xla_force_host_platform_device_count=4) ==")

    # 5 — serving metrics
    s = engine.stats()
    print("\n== serving stats ==")
    print(f"   requests {s['requests']}, images {s['images']}, "
          f"batches {s['batches']}")
    print(f"   latency p50 {s['latency_p50_s'] * 1e3:.1f} ms, "
          f"p99 {s['latency_p99_s'] * 1e3:.1f} ms; sustained "
          f"{s['sustained_ips']:,.0f} img/s (host sim)")
    print(f"   padding overhead {100 * s['padding_fraction']:.1f}% of "
          f"executed slots; retraces since warmup "
          f"{s['retraces_since_warmup']}")
    print(f"   plan cache {s['plan_cache']['hits']}h/"
          f"{s['plan_cache']['misses']}m; compiled wrappers "
          f"{s['compile_cache']['entries']}")


if __name__ == "__main__":
    main()

"""Quickstart: the paper's technique in five snippets.

1. Scalability analysis (Fig. 9): how large can a HEANA DPU be?
2. A photonic matmul: HEANA vs AMW vs exact numerics.
3. The Pallas TAOM kernel vs its oracle.
4. System-level FPS/FPS-per-watt (Fig. 11) for ResNet50.
5. An LM forward pass running *through* the photonic backend.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import Backend, PhotonicConfig, max_dpe_size
from repro.core.perf_model import AcceleratorConfig, cnn_inference
from repro.core.photonic_gemm import design_point
from repro.core.types import Dataflow
from repro.kernels import ops
from repro.models.cnn import CNN_ZOO


def main():
    # 1 — scalability (paper Fig. 9): the hitless TAOM arrangement lets
    # HEANA run much wider optical dot products than AMW/MAW.
    print("== DPU size N at 4-bit, 1 GS/s ==")
    for be in ("heana", "amw", "maw"):
        print(f"  {be:6s} N = {max_dpe_size(be, 4, 1.0)}")

    # 2 — photonic numerics as a drop-in matmul
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (8, 512))
    w = jax.random.normal(jax.random.fold_in(key, 1), (512, 64))
    exact = x @ w
    print("\n== photonic matmul rel-RMSE vs exact (4-bit design points) ==")
    for be in (Backend.HEANA, Backend.AMW):
        cfg = design_point(be, bits=4, data_rate_gsps=1.0)
        out = ops.photonic_matmul(x, w, cfg, key=jax.random.fold_in(key, 2))
        err = float(jnp.linalg.norm(out - exact) / jnp.linalg.norm(exact))
        print(f"  {be.value:6s} N={cfg.dpe_size:3d}  rel-rmse={err:.4f}")

    # 3 — the Pallas kernel path agrees with the jnp oracle
    cfg = PhotonicConfig(backend=Backend.HEANA, bits=8, dpe_size=128,
                         noise_enabled=False)
    a = ops.photonic_matmul(x, w, cfg, impl="pallas")
    b = ops.photonic_matmul(x, w, cfg, impl="ref")
    print(f"\n== pallas vs oracle max diff: "
          f"{float(jnp.max(jnp.abs(a - b))):.2e} ==")

    # 4 — system-level evaluation (paper Fig. 11, ResNet50 @ 1 GS/s)
    print("\n== ResNet50 FPS / FPS-per-W (equal-area, 1 GS/s) ==")
    layers = CNN_ZOO["resnet50"]()
    for be, flow in (("heana", Dataflow.OS), ("amw", Dataflow.WS),
                     ("maw", Dataflow.WS)):
        r = cnn_inference(layers, AcceleratorConfig.equal_area(be, flow, 1.0))
        print(f"  {be:6s}-{flow.value}: {r.fps:12.0f} FPS   "
              f"{r.fps_per_watt:8.2f} FPS/W")

    # 5 — an LM forward through the photonic backend
    from repro.configs import get_config
    from repro.models import model_zoo as zoo
    from repro.models.layers import PhotonicCtx
    cfg_lm = get_config("qwen2-0.5b", smoke=True)
    params = zoo.init_params(cfg_lm, key)
    tokens = jax.random.randint(key, (2, 32), 0, cfg_lm.vocab_size)
    batch = {"tokens": tokens, "targets": tokens}
    for name, ctx in (("exact", PhotonicCtx()),
                      ("heana-8bit", PhotonicCtx(cfg=PhotonicConfig(
                          backend=Backend.HEANA, bits=8, adc_bits=12,
                          dpe_size=128, noise_enabled=False), impl="ref"))):
        loss = zoo.loss_fn(params, batch, cfg_lm, ctx=ctx)
        print(f"  qwen2-0.5b(smoke) loss under {name:10s}: {float(loss):.4f}")


if __name__ == "__main__":
    main()

"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.photonic_gemm import sample_noise
from repro.core.taom import quantize
from repro.core.types import Backend, PhotonicConfig
from repro.kernels import ops, ref
from repro.kernels.taom_gemm import calibrated_adc_fs, taom_gemm_quantized


def _rand(shape, seed=0, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype)


class TestTaomGemmKernel:
    @pytest.mark.parametrize("m,k,d", [
        (8, 83, 8), (24, 300, 40), (128, 256, 128), (1, 1, 1),
        (7, 130, 3), (130, 4096, 64),
    ])
    @pytest.mark.parametrize("backend", [Backend.HEANA, Backend.AMW,
                                         Backend.MAW])
    def test_shape_sweep_matches_oracle(self, m, k, d, backend):
        cfg = PhotonicConfig(backend=backend, bits=4, dpe_size=83, adc_bits=8)
        x, w = _rand((m, k), k + 1), _rand((k, d), d + 1)
        xq, _ = quantize(x, cfg.bits)
        wq, _ = quantize(w, cfg.bits, axis=0)
        noise = sample_noise(jax.random.PRNGKey(7), x.shape, w.shape, cfg)
        if backend in (Backend.AMW, Backend.MAW):
            noise = jnp.moveaxis(noise, -2, 0)
        fs = calibrated_adc_fs(k, cfg)
        got = taom_gemm_quantized(xq, wq, noise, cfg, fs, interpret=True)
        want = ref.taom_gemm_reference(xq, wq, noise, cfg, fs)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-3)

    @pytest.mark.parametrize("dpe", [1, 7, 83, 128, 200])
    def test_dpe_size_sweep(self, dpe):
        cfg = PhotonicConfig(backend=Backend.HEANA, bits=6, dpe_size=dpe,
                             adc_bits=10)
        x, w = _rand((16, 260), 3), _rand((260, 24), 4)
        xq, _ = quantize(x, cfg.bits)
        wq, _ = quantize(w, cfg.bits, axis=0)
        noise = sample_noise(jax.random.PRNGKey(8), x.shape, w.shape, cfg)
        fs = calibrated_adc_fs(260, cfg)
        got = taom_gemm_quantized(xq, wq, noise, cfg, fs, interpret=True)
        want = ref.taom_gemm_reference(xq, wq, noise, cfg, fs)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-3)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtype_sweep_via_wrapper(self, dtype):
        cfg = PhotonicConfig(backend=Backend.HEANA, bits=4, dpe_size=83)
        x, w = _rand((12, 200), 5, dtype), _rand((200, 16), 6, dtype)
        a = ops.photonic_matmul(x, w, cfg, key=jax.random.PRNGKey(9),
                                impl="pallas")
        b = ops.photonic_matmul(x, w, cfg, key=jax.random.PRNGKey(9),
                                impl="ref")
        assert a.dtype == dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-2)

    def test_wrapper_batched_input(self):
        cfg = PhotonicConfig(backend=Backend.HEANA, bits=8, dpe_size=64,
                             noise_enabled=False)
        x, w = _rand((2, 3, 96), 10), _rand((96, 8), 11)
        out = ops.photonic_matmul(x, w, cfg, impl="pallas")
        assert out.shape == (2, 3, 8)
        want = ops.photonic_matmul(x, w, cfg, impl="ref")
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_exact_backend_bypasses_kernel(self):
        cfg = PhotonicConfig(backend=Backend.EXACT)
        x, w = _rand((4, 32), 12), _rand((32, 8), 13)
        np.testing.assert_allclose(
            np.asarray(ops.photonic_matmul(x, w, cfg)), np.asarray(x @ w),
            rtol=1e-6)

    def test_ste_gradients_through_kernel(self):
        cfg = PhotonicConfig(backend=Backend.HEANA, bits=4, dpe_size=83,
                             noise_enabled=False)
        x, w = _rand((8, 166), 14), _rand((166, 8), 15)

        def loss(x, w):
            return jnp.sum(ops.photonic_matmul(x, w, cfg, impl="pallas") ** 2)

        gx, gw = jax.grad(loss, argnums=(0, 1))(x, w)
        out = ops.photonic_matmul(x, w, cfg, impl="pallas")
        np.testing.assert_allclose(np.asarray(gx), np.asarray(2 * out @ w.T),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(gw), np.asarray(x.T @ (2 * out)),
                                   rtol=1e-4, atol=1e-4)

    @given(m=st.integers(1, 40), k=st.integers(1, 300), d=st.integers(1, 40),
           bits=st.sampled_from([2, 4, 8]))
    @settings(max_examples=12, deadline=None)
    def test_property_kernel_oracle_parity(self, m, k, d, bits):
        cfg = PhotonicConfig(backend=Backend.HEANA, bits=bits, dpe_size=83,
                             adc_bits=10)
        x, w = _rand((m, k), m * 7 + k), _rand((k, d), d * 13 + 1)
        xq, _ = quantize(x, cfg.bits)
        wq, _ = quantize(w, cfg.bits, axis=0)
        noise = sample_noise(jax.random.PRNGKey(m + d), x.shape, w.shape, cfg)
        fs = calibrated_adc_fs(k, cfg)
        got = taom_gemm_quantized(xq, wq, noise, cfg, fs, interpret=True)
        want = ref.taom_gemm_reference(xq, wq, noise, cfg, fs)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-3)


class TestSsdScan:
    def _naive(self, x, dt, a, b, c):
        ys, states = [], []
        for i in range(x.shape[0]):
            y, s = ref.ssd_scan_reference(
                x[i][:, None, :], dt[i][:, None], a[i][None],
                b[i][:, None, :], c[i][:, None, :])
            ys.append(y[:, 0])
            states.append(s[0])
        return jnp.stack(ys), jnp.stack(states)

    def _inputs(self, bh, l, p, s, seed=0):
        ks = jax.random.split(jax.random.PRNGKey(seed), 5)
        x = jax.random.normal(ks[0], (bh, l, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (bh, l)))
        a = -jnp.exp(jax.random.normal(ks[2], (bh,)))
        b = jax.random.normal(ks[3], (bh, l, s))
        c = jax.random.normal(ks[4], (bh, l, s))
        return x, dt, a, b, c

    @pytest.mark.parametrize("bh,l,p,s,chunk", [
        (2, 32, 8, 16, 8), (3, 40, 16, 24, 16), (1, 128, 64, 32, 128),
        (2, 33, 8, 8, 16),   # ragged L -> padding path
    ])
    def test_pallas_and_jax_match_naive(self, bh, l, p, s, chunk):
        x, dt, a, b, c = self._inputs(bh, l, p, s, seed=l)
        y_ref, st_ref = self._naive(x, dt, a, b, c)
        for impl in ("jax", "pallas"):
            y, st = ops.ssd_scan(x, dt, a, b, c, chunk=chunk, impl=impl)
            np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                       rtol=1e-4, atol=1e-4, err_msg=impl)
            np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                                       rtol=1e-4, atol=1e-4, err_msg=impl)

    def test_decode_step_matches_scan(self):
        bh, l, p, s = 2, 24, 8, 12
        x, dt, a, b, c = self._inputs(bh, l, p, s, seed=5)
        y_scan, st_scan = ops.ssd_scan(x, dt, a, b, c, chunk=8, impl="jax")
        st = jnp.zeros((bh, p, s))
        ys = []
        for t in range(l):
            yt, st = ops.ssd_decode_step(st, x[:, t], dt[:, t], a,
                                         b[:, t], c[:, t])
            ys.append(yt)
        np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                                   np.asarray(y_scan), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(st), np.asarray(st_scan),
                                   rtol=1e-4, atol=1e-4)

    def test_jax_impl_differentiable(self):
        x, dt, a, b, c = self._inputs(2, 16, 4, 8, seed=9)

        def loss(x, b, c):
            y, _ = ops.ssd_scan(x, dt, a, b, c, chunk=8, impl="jax")
            return jnp.sum(y ** 2)

        grads = jax.grad(loss, argnums=(0, 1, 2))(x, b, c)
        assert all(bool(jnp.all(jnp.isfinite(g))) for g in grads)

    def test_state_continuation_property(self):
        # Scanning [0:L] must equal scanning [0:L/2] then continuing with
        # the decode step over the second half.
        bh, l, p, s = 1, 16, 4, 6
        x, dt, a, b, c = self._inputs(bh, l, p, s, seed=11)
        y_full, _ = ops.ssd_scan(x, dt, a, b, c, chunk=8, impl="jax")
        _, st_half = ops.ssd_scan(x[:, :8], dt[:, :8], a, b[:, :8], c[:, :8],
                                  chunk=8, impl="jax")
        st = st_half
        ys = []
        for t in range(8, l):
            yt, st = ops.ssd_decode_step(st, x[:, t], dt[:, t], a,
                                         b[:, t], c[:, t])
            ys.append(yt)
        np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                                   np.asarray(y_full[:, 8:]),
                                   rtol=1e-4, atol=1e-4)

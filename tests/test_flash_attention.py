"""Flash-attention kernel sweeps vs dense oracle (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import (flash_attention_fwd,
                                           flash_attention_reference)


def _qkv(bh, s, d, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return tuple(jax.random.normal(k, (bh, s, d), dtype) for k in ks)


class TestFlashAttention:
    @pytest.mark.parametrize("s,d,bq,bk", [
        (32, 16, 8, 8), (64, 32, 16, 16), (128, 64, 128, 128),
        (48, 16, 16, 8),
    ])
    def test_causal_matches_reference(self, s, d, bq, bk):
        q, k, v = _qkv(2, s, d, seed=s)
        got = flash_attention_fwd(q, k, v, causal=True, block_q=bq,
                                  block_k=bk, interpret=True)
        want = flash_attention_reference(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("window", [4, 16])
    def test_sliding_window(self, window):
        q, k, v = _qkv(1, 40, 16, seed=window)
        got = flash_attention_fwd(q, k, v, causal=True, window=window,
                                  block_q=8, block_k=8, interpret=True)
        want = flash_attention_reference(q, k, v, causal=True,
                                         window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    def test_non_causal_with_padding(self):
        # S=33 pads to 40 with block 8: padded keys must get zero weight
        q, k, v = _qkv(1, 33, 16, seed=7)
        got = flash_attention_fwd(q, k, v, causal=False, block_q=8,
                                  block_k=8, interpret=True)
        want = flash_attention_reference(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    def test_bfloat16(self):
        q, k, v = _qkv(2, 32, 32, seed=3, dtype=jnp.bfloat16)
        got = flash_attention_fwd(q, k, v, block_q=16, block_k=16,
                                  interpret=True)
        want = flash_attention_reference(q, k, v)
        assert got.dtype == jnp.bfloat16
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=3e-2, atol=3e-2)

    def test_ragged_seq_padding_path(self):
        q, k, v = _qkv(1, 37, 16, seed=11)
        got = flash_attention_fwd(q, k, v, causal=True, block_q=16,
                                  block_k=16, interpret=True)
        want = flash_attention_reference(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


class TestFlashAttentionInModel:
    def test_model_attention_impl_parity(self):
        """attn_impl='pallas' == default XLA path, incl. padded heads and
        sliding windows."""
        import dataclasses
        from repro.models import attention as A
        from repro.models import layers as L

        spec = A.AttnSpec(d_model=48, num_heads=3, num_kv_heads=1,
                          head_dim=16, head_pad=4)
        p = A.make_attention(L.ParamMaker(jax.random.PRNGKey(0),
                                          dtype=jnp.float32), "a", spec)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 48))
        pos = jnp.broadcast_to(jnp.arange(24)[None], (2, 24))
        for s in (spec, dataclasses.replace(spec, window=8)):
            o_xla, _ = A.attention(p, x, pos, s)
            o_pal, _ = A.attention(p, x, pos, s, attn_impl="pallas")
            np.testing.assert_allclose(np.asarray(o_xla), np.asarray(o_pal),
                                       rtol=2e-5, atol=2e-5)

"""Property-based tests for the lowering IR (ISSUE 3 satellite).

Random op-graphs — random strides, odd/rectangular spatial dims,
depthwise/residual/pool mixes — must satisfy:

  * ``graph_apply`` (the im2col/block-diagonal GEMM lowering, exact
    matmul) equals the direct jax.lax.conv reference;
  * ``graph_gemms``'s analytic rows equal the shapes the walker
    actually produces (shape inference is truthful);
  * every planned tile covers its GEMM and its padded dims divide by
    the tile exactly (the kernel's grid arithmetic cannot under-run).

Optional-dependency guard: the whole module skips cleanly when
hypothesis isn't installed (CI images without it still collect).
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")
import jax                                                  # noqa: E402
import numpy as np                                          # noqa: E402
from hypothesis import given, settings, strategies as st    # noqa: E402

from repro.core import perf_model as pm                     # noqa: E402
from repro.core.types import Dataflow                       # noqa: E402
from repro.exec import PlanCache                            # noqa: E402
from repro.exec.scheduler import choose_tile, plan_layer    # noqa: E402
from repro.models import lowering as lw                     # noqa: E402
from repro.models.lowering import LayerGemm                 # noqa: E402

HEANA = pm.AcceleratorConfig.equal_area("heana", Dataflow.OS, 1.0)


@st.composite
def chain_graphs(draw):
    """A random straight-line net with optional residual/pool detours:
    stem conv -> K blocks (conv | depthwise | conv+residual | pool) ->
    global pool -> fc.  Strides and kernel sizes vary; spatial dims are
    drawn odd/rectangular on purpose."""
    h = draw(st.integers(7, 14))
    w = draw(st.integers(7, 14))
    cin = draw(st.integers(1, 3))
    nodes = [lw.input_node(cin),
             lw.conv("stem", "input", draw(st.integers(2, 6)),
                     kk=draw(st.sampled_from([1, 3])),
                     stride=draw(st.sampled_from([1, 2])))]
    prev, prev_c = "stem", nodes[-1].cout
    n_blocks = draw(st.integers(1, 3))
    for i in range(n_blocks):
        kind = draw(st.sampled_from(
            ["conv", "depthwise", "residual", "pool"]))
        name = f"b{i}"
        if kind == "conv":
            cout = draw(st.integers(2, 8))
            nodes.append(lw.conv(name, prev, cout,
                                 kk=draw(st.sampled_from([1, 3, 5])),
                                 stride=draw(st.sampled_from([1, 2])),
                                 relu=draw(st.booleans())))
            prev, prev_c = name, cout
        elif kind == "depthwise":
            nodes.append(lw.dwconv(name, prev,
                                   stride=draw(st.sampled_from([1, 2])),
                                   relu=draw(st.booleans())))
            prev = name
        elif kind == "residual":
            # two parallel 1x1 convs to the same channel count, added
            cout = draw(st.integers(2, 6))
            nodes.append(lw.conv(f"{name}_l", prev, cout, kk=1))
            nodes.append(lw.conv(f"{name}_r", prev, cout, kk=3))
            nodes.append(lw.residual(name, f"{name}_l", f"{name}_r",
                                     relu=draw(st.booleans())))
            prev, prev_c = name, cout
        else:
            # 'same'-padded max pool tiles any dims (odd included)
            nodes.append(lw.pool(name, prev, kind="max",
                                 size=draw(st.sampled_from([2, 3])),
                                 stride=draw(st.sampled_from([1, 2])),
                                 padding="same"))
            prev = name
    nodes.append(lw.global_avg("gap", prev))
    nodes.append(lw.fc("out", "gap", draw(st.integers(2, 5))))
    return lw.OpGraph(tuple(nodes)), (h, w)


@settings(max_examples=25, deadline=None)
@given(chain_graphs(), st.integers(0, 2 ** 31 - 1))
def test_lowered_apply_equals_direct_reference(graph_hw, seed):
    """The GEMM lowering computes the same function as lax.conv —
    strides, odd/rect dims, depthwise, residual and pooling included."""
    graph, in_hw = graph_hw
    key = jax.random.PRNGKey(seed)
    params = lw.init_params(graph, key, in_hw)
    x = jax.random.normal(jax.random.fold_in(key, 1),
                          (2, *in_hw, graph.input.cout))
    got = lw.graph_apply(params, x, graph)
    want = lw.direct_forward(params, x, graph)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


@settings(max_examples=25, deadline=None)
@given(chain_graphs())
def test_graph_gemms_are_truthful(graph_hw):
    """Analytic rows == executed rows: every conv/fc LayerGemm's C is
    exactly the pixel count the walker produces at that node."""
    graph, in_hw = graph_hw
    shapes = lw.infer_shapes(graph, in_hw)
    gemms = lw.graph_gemms(graph, in_hw)
    assert [g.name for g in gemms] == [n.name for n in graph.gemm_nodes]
    for g, node in zip(gemms, graph.gemm_nodes):
        oh, ow, oc = shapes[node.name]
        if node.op == "fc":
            assert g.c == 1 and g.d == oc
        elif node.op == "depthwise_conv":
            assert g.c == oh * ow and g.d == 1
            assert g.count == shapes[node.inputs[0]][2]
        else:
            assert g.c == oh * ow and g.d == oc
        assert g.macs > 0


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 5000), st.integers(1, 600), st.integers(1, 3000))
def test_planned_tile_divides_its_gemm_dims(m, d, k):
    """Every tile covers the GEMM and the padded dims divide exactly by
    the chosen blocks — the kernel's grid can neither under-run nor
    leave a ragged last step."""
    t = choose_tile(m, d, k, dpe_size=83)
    mp = t.grid_m * t.block_m
    dp = t.grid_d * t.block_d
    assert mp >= m and dp >= d
    assert mp % t.block_m == 0 and dp % t.block_d == 0
    assert mp - t.block_m < m       # no superfluous trailing grid step
    assert dp - t.block_d < d
    assert t.block_m % 8 == 0 and t.block_d % 128 == 0
    assert t.n_chunks >= 1


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 4000), st.integers(1, 500), st.integers(1, 2000),
       st.integers(2, 64))
def test_depthwise_plan_tile_covers_executed_gemm(m, d, k, count):
    """Depthwise layers plan their tile against the fused block-diagonal
    GEMM (M, k*count) @ (k*count, count) the executor actually runs."""
    layer = LayerGemm("dw", m, k, 1, count=count)
    plan = plan_layer(layer, HEANA, cache=PlanCache())
    assert plan.tile.grid_d * plan.tile.block_d >= count
    assert plan.tile.grid_m * plan.tile.block_m >= m
    assert plan.tile.n_chunks == max(1, -(-k * count // HEANA.n))

"""Pipeline-parallel schedule tests (single-device mesh: the schedule and
collective pattern are what's under test; stage count 1..n devices)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.parallel.pipeline_parallel import bubble_fraction, pipeline_forward


def _block(p, x):
    return jnp.tanh(x @ p["w"] + p["b"])


def _stage_params(n_stages, d, key=0):
    k = jax.random.PRNGKey(key)
    return {"w": jax.random.normal(k, (n_stages, d, d)) / jnp.sqrt(d),
            "b": jnp.zeros((n_stages, d))}


class TestPipeline:
    def test_bubble_fraction(self):
        assert bubble_fraction(4, 4) == pytest.approx(3 / 7)
        assert bubble_fraction(1, 8) == 0.0
        assert bubble_fraction(4, 28) < 0.1

    @pytest.mark.parametrize("m", [1, 2, 5])
    def test_matches_sequential_single_stage_mesh(self, m):
        """On however many devices exist, PP output == sequential layers."""
        n = len(jax.devices())
        mesh = jax.make_mesh((n,), ("stage",))
        d = 8
        params = _stage_params(n, d)
        x = jax.random.normal(jax.random.PRNGKey(1), (m, 4, d))

        y_pp = pipeline_forward(_block, params, x, mesh, "stage")

        def sequential(mb):
            for s in range(n):
                mb = _block(jax.tree.map(lambda a, s=s: a[s], params), mb)
            return mb

        y_ref = jax.vmap(sequential)(x)
        np.testing.assert_allclose(np.asarray(y_pp), np.asarray(y_ref),
                                   rtol=2e-5, atol=2e-5)

    def test_jit_compiles_one_program(self):
        n = len(jax.devices())
        mesh = jax.make_mesh((n,), ("stage",))
        params = _stage_params(n, 8)
        x = jax.random.normal(jax.random.PRNGKey(2), (4, 2, 8))
        f = jax.jit(lambda p, x: pipeline_forward(_block, p, x, mesh))
        np.testing.assert_allclose(
            np.asarray(f(params, x)),
            np.asarray(pipeline_forward(_block, params, x, mesh)),
            rtol=1e-5)

"""Operating-point properties (ISSUE 5 satellites).

The unified hardware operating point (core.hw.OperatingPoint) must be a
faithful front door to the existing solvers:

  * the solved DPE size N is non-increasing in bit-precision and in data
    rate for all three DPU organizations (hypothesis-guarded, mirroring
    the Fig. 9 surface's monotonicity);
  * the OperatingPoint-derived detection sigma equals
    ``noise.relative_noise_sigma`` evaluated at the link-budget power —
    checked at the paper's Fig. 9 / Table 2 anchor points (B=4: HEANA
    83/42/30, AMW 36/17/12, MAW 43/22/15);
  * the fanned-out kernel/scheduler config pair is coherent by
    construction, and incoherent hand-edits are detected.

Optional-dependency guard: the hypothesis-driven class skips cleanly
when hypothesis isn't installed (same pattern as test_graph_props.py).
"""
import dataclasses

import pytest

from repro.core import hw, noise, scalability
from repro.core.types import Backend, Dataflow, OpticalParams

BACKENDS = ("heana", "amw", "maw")

# Paper Fig. 9 / Table 2 anchors at B=4 as the repo's solver reproduces
# them (MAW@5GS/s is the documented off-by-one vs the published table:
# solver 22, Table 2 21).
SOLVER_ANCHORS = {
    "heana": {1.0: 83, 5.0: 42, 10.0: 30},
    "amw": {1.0: 36, 5.0: 17, 10.0: 12},
    "maw": {1.0: 43, 5.0: 22, 10.0: 15},
}


class TestAnchorSigmas:
    @pytest.mark.parametrize("backend", BACKENDS)
    @pytest.mark.parametrize("dr", [1.0, 5.0, 10.0])
    def test_design_point_hits_solver_anchor(self, backend, dr):
        op = hw.OperatingPoint.design(backend, Dataflow.OS, bits=4,
                                      data_rate_gsps=dr)
        assert op.n == SOLVER_ANCHORS[backend][dr]

    @pytest.mark.parametrize("backend", BACKENDS)
    @pytest.mark.parametrize("dr", [1.0, 5.0, 10.0])
    def test_sigma_equals_noise_module_at_link_budget_power(
            self, backend, dr):
        """The OperatingPoint's sigma IS noise.relative_noise_sigma at
        the Eq. 3 link-budget power — no second noise model."""
        op = hw.OperatingPoint.design(backend, Dataflow.OS, bits=4,
                                      data_rate_gsps=dr)
        expect = noise.relative_noise_sigma(op.pd_power_dbm(), dr,
                                            op.optics)
        assert op.noise_sigma() == expect
        # and the link budget delivers at least the solved precision
        assert op.enob() >= 4.0

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_kernel_config_carries_the_same_sigma(self, backend):
        """photonic_gemm's operating power for the derived kernel config
        equals the OperatingPoint's own link-budget power — the sigma the
        kernels inject is the sigma the point declares."""
        from repro.core import photonic_gemm as pg
        op = hw.OperatingPoint.design(backend, Dataflow.OS, bits=4)
        cfg = op.kernel_config()
        assert pg.operating_pd_power_dbm(cfg) == op.pd_power_dbm()


class TestOperatingPointContract:
    def test_equal_area_matches_table2(self):
        for be in BACKENDS:
            for dr in (1.0, 5.0, 10.0):
                op = hw.OperatingPoint.equal_area(be, Dataflow.OS, dr)
                assert (op.n, op.n_dpus) == \
                    scalability.table2_dpu_config(be, dr)
                assert op.bits == 4

    def test_config_pair_coherent_by_construction(self):
        for be in BACKENDS:
            op = hw.OperatingPoint.equal_area(be, Dataflow.WS, 1.0)
            cfg, acc = op.kernel_config(), op.accelerator_config()
            assert hw.kernel_plan_mismatches(cfg, acc, op) == []
            assert cfg.backend.value == acc.backend
            assert cfg.dpe_size == acc.n == op.n
            assert cfg.dataflow == acc.dataflow == Dataflow.WS

    def test_mismatch_reported_per_field(self):
        op = hw.OperatingPoint.equal_area("heana", Dataflow.OS, 1.0)
        acc = op.accelerator_config()
        bad = op.kernel_config(bits=8, dpe_size=64)
        probs = hw.kernel_plan_mismatches(bad, acc, op)
        assert any("bits" in p for p in probs)
        assert any("DPE size" in p for p in probs)
        # optics disagreement is caught too (different link budget)
        weird = op.kernel_config(
            optics=dataclasses.replace(OpticalParams(), p_laser_dbm=13.0))
        assert any("optics" in p
                   for p in hw.kernel_plan_mismatches(weird, acc, op))

    def test_hand_set_pd_power_caught(self):
        """A hand-set pd_power_dbm changes the injected sigma behind the
        solved precision's back — v4 plans reject it."""
        op = hw.OperatingPoint.equal_area("heana", Dataflow.OS, 1.0)
        acc = op.accelerator_config()
        bad = op.kernel_config(pd_power_dbm=-30.0)
        assert any("PD power" in p
                   for p in hw.kernel_plan_mismatches(bad, acc, op))
        # explicitly setting the SAME power the link budget derives is
        # coherent (and so is the default None)
        same = op.kernel_config(pd_power_dbm=op.pd_power_dbm())
        assert hw.kernel_plan_mismatches(same, acc, op) == []

    def test_non_photonic_backends_exempt(self):
        op = hw.OperatingPoint.equal_area("heana", Dataflow.OS, 1.0)
        exact = op.kernel_config(backend=Backend.EXACT, bits=8, dpe_size=7)
        assert hw.kernel_plan_mismatches(
            exact, op.accelerator_config(), op) == []

    def test_infeasible_point_raises_clearly(self):
        with pytest.raises(ValueError, match="optically infeasible"):
            hw.OperatingPoint.design("amw", bits=8, data_rate_gsps=10.0)

    def test_unknown_backend_raises(self):
        with pytest.raises(ValueError, match="unknown photonic backend"):
            hw.OperatingPoint(backend="exact")

    def test_design_point_wrapper_delegates(self):
        """photonic_gemm.design_point now derives through the operating
        point — same N, same fields as before the refactor."""
        from repro.core.photonic_gemm import design_point
        cfg = design_point(Backend.HEANA, 4, 1.0, adc_bits=12)
        assert cfg.dpe_size == 83 and cfg.bits == 4 and cfg.adc_bits == 12
        # lenient fallback across the RIN cliff is preserved
        cliff = design_point(Backend.AMW, 8, 10.0)
        assert cliff.dpe_size == 1

    def test_event_energies_positive_and_backend_aware(self):
        h = hw.OperatingPoint.equal_area("heana", Dataflow.OS, 1.0)
        a = hw.OperatingPoint.equal_area("amw", Dataflow.OS, 1.0)
        eh, ea = h.event_energies(), a.event_energies()
        for e in (eh, ea):
            assert all(v > 0 for v in dataclasses.asdict(e).values())
        # HEANA's 10 GS/s DAC: less energy per converted symbol
        assert eh.dac_j < ea.dac_j


class TestSolverMonotonicityGrid:
    """Deterministic full-grid sweep (runs everywhere): solved N is
    non-increasing in bits and in data rate — the Fig. 9 surface's shape
    — for every DPU organization."""

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_n_non_increasing_in_bits_and_rate(self, backend):
        drs = (1.0, 2.5, 5.0, 10.0)
        surface = {(b, dr): scalability.max_dpe_size(backend, b, dr)
                   for b in range(1, 9) for dr in drs}
        for dr in drs:
            col = [surface[(b, dr)] for b in range(1, 9)]
            assert all(a >= b for a, b in zip(col, col[1:])), \
                f"{backend}: N not monotone in bits at DR={dr}: {col}"
        for b in range(1, 9):
            row = [surface[(b, dr)] for dr in drs]
            assert all(a >= b2 for a, b2 in zip(row, row[1:])), \
                f"{backend}: N not monotone in DR at B={b}: {row}"


# Randomized reinforcement of the same properties when hypothesis is
# available (same optional-dependency posture as test_graph_props.py —
# but the deterministic grid above runs regardless).
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                  # pragma: no cover
    pass
else:
    class TestSolverMonotonicityHypothesis:
        @settings(max_examples=30, deadline=None)
        @given(st.sampled_from(BACKENDS), st.integers(1, 8),
               st.integers(1, 8),
               st.floats(0.5, 12.0, allow_nan=False))
        def test_n_non_increasing_in_bits(self, backend, b1, b2, dr):
            lo, hi = sorted((b1, b2))
            assert scalability.max_dpe_size(backend, hi, dr) <= \
                scalability.max_dpe_size(backend, lo, dr)

        @settings(max_examples=30, deadline=None)
        @given(st.sampled_from(BACKENDS), st.integers(1, 8),
               st.floats(0.5, 12.0, allow_nan=False),
               st.floats(0.5, 12.0, allow_nan=False))
        def test_n_non_increasing_in_data_rate(self, backend, bits,
                                               d1, d2):
            lo, hi = sorted((d1, d2))
            assert scalability.max_dpe_size(backend, bits, hi) <= \
                scalability.max_dpe_size(backend, bits, lo)

        @settings(max_examples=20, deadline=None)
        @given(st.sampled_from(BACKENDS), st.integers(1, 6),
               st.sampled_from([1.0, 5.0]))
        def test_operating_point_consistent_with_solver(self, backend,
                                                        bits, dr):
            """Feasible points: OperatingPoint.design == raw solver
            output, and the derived configs agree on every shared
            field."""
            n = scalability.max_dpe_size(backend, bits, dr)
            if n < 1:
                with pytest.raises(ValueError):
                    hw.OperatingPoint.design(backend, bits=bits,
                                             data_rate_gsps=dr)
                return
            op = hw.OperatingPoint.design(backend, bits=bits,
                                          data_rate_gsps=dr)
            assert op.n == n
            cfg, acc = op.kernel_config(), op.accelerator_config()
            assert cfg.dpe_size == acc.n == n
            assert cfg.data_rate_gsps == acc.data_rate_gsps == dr
            assert hw.kernel_plan_mismatches(cfg, acc, op) == []

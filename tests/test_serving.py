"""Serving-engine tests (exec.serving — ISSUE 4).

Pins the serving contracts: bucket selection, padding bit-exactness
(a padded request equals the exact-size batch), chunking semantics,
zero retraces after warmup, thread-safety of concurrent serving (and of
the executor's module caches it leans on), micro-batcher plumbing and
error propagation, noise-key handling, data-parallel bit-identity (when
several devices are visible), and the metrics/stats surface.
"""
import threading

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import perf_model as pm
from repro.core.types import Backend, Dataflow, PhotonicConfig
from repro.exec import (MicroBatcher, PlanCache, ServingEngine, bucket_for,
                        execute_cnn, plan_for_network,
                        power_of_two_buckets, schedule_buckets, trace_count)
from repro.models.cnn import build_small_cnn, lowered_gemms

HEANA = pm.AcceleratorConfig.equal_area("heana", Dataflow.OS, 1.0)


def _cfg(noise: bool = False) -> PhotonicConfig:
    # bits=6 keeps every integer partial sum bit-exactness-safe.
    return PhotonicConfig(backend=Backend.HEANA, bits=6, dpe_size=83,
                          noise_enabled=noise)


@pytest.fixture(scope="module")
def served():
    """One warmed-up engine shared by the module (warmup compiles)."""
    key = jax.random.PRNGKey(0)
    params = build_small_cnn(key)
    engine = ServingEngine(params, HEANA, _cfg(), max_batch=8,
                           plan_cache=PlanCache())
    engine.warmup()
    return key, params, engine


def _images(key, i: int, n: int) -> jnp.ndarray:
    return jax.random.normal(jax.random.fold_in(key, i), (n, 16, 16, 3))


class TestBuckets:
    def test_power_of_two_buckets(self):
        assert power_of_two_buckets(1) == (1,)
        assert power_of_two_buckets(5) == (1, 2, 4, 8)
        assert power_of_two_buckets(8) == (1, 2, 4, 8)
        with pytest.raises(ValueError, match="max_batch"):
            power_of_two_buckets(0)

    def test_bucket_for_picks_smallest_fit(self):
        buckets = (1, 2, 4, 8)
        assert [bucket_for(n, buckets) for n in (1, 2, 3, 5, 8)] == \
            [1, 2, 4, 8, 8]
        with pytest.raises(ValueError, match="exceeds"):
            bucket_for(9, buckets)

    def test_engine_plans_one_per_bucket(self, served):
        _, _, engine = served
        assert set(engine.plans) == set(engine.buckets) == {1, 2, 4, 8}
        for b, plan in engine.plans.items():
            assert plan.batch == b

    def test_schedule_buckets_shares_cache(self):
        params = build_small_cnn(jax.random.PRNGKey(0))
        gemms = lowered_gemms(params)
        cache = PlanCache()
        schedule_buckets(gemms, HEANA, (1, 2, 4), cache=cache)
        replans = schedule_buckets(gemms, HEANA, (1, 2, 4), cache=cache)
        assert all(p.cache_misses == 0 for p in replans.values())


class TestBucketedServing:
    @pytest.mark.parametrize("n", [1, 3, 5, 8])
    def test_padded_request_bitwise_equals_exact_batch(self, served, n):
        """Zero padding to the bucket is numerics-neutral: the served
        logits equal an exact-size batch through execute_cnn bitwise."""
        key, params, engine = served
        x = _images(key, 10 + n, n)
        got = engine.infer(x)
        plan = plan_for_network(params, HEANA, batch=n, cache=PlanCache())
        ref = execute_cnn(params, x, plan, _cfg()).logits
        assert got.shape == (n, 10)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    def test_oversize_request_equals_per_chunk_runs(self, served):
        """N > max_bucket chunks into top-bucket pieces; each chunk is
        its own batch (per-batch quantize scale), so the result equals
        the concatenation of exact-size chunk runs."""
        key, params, engine = served
        x = _images(key, 99, 11)                 # chunks: 8 + 3(->4)
        got = engine.infer(x)
        r8 = execute_cnn(params, x[:8], plan_for_network(
            params, HEANA, batch=8, cache=PlanCache()), _cfg()).logits
        r3 = execute_cnn(params, x[8:], plan_for_network(
            params, HEANA, batch=3, cache=PlanCache()), _cfg()).logits
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(jnp.concatenate([r8, r3])))

    def test_zero_retraces_after_warmup(self, served):
        key, _, engine = served
        for n in (1, 2, 3, 8):                   # prime every bucket once
            engine.infer(_images(key, 200 + n, n))
        before = trace_count()
        for n in (1, 2, 3, 4, 5, 7, 8, 11):
            engine.infer(_images(key, 300 + n, n))
        assert trace_count() == before

    def test_retrace_accounting_is_engine_local(self, served):
        """Another engine warming up (new cfg -> new traces) must not
        show up in this engine's retraces_since_warmup."""
        key, params, engine = served
        assert engine.stats()["retraces_since_warmup"] == 0
        other_cfg = PhotonicConfig(backend=Backend.HEANA, bits=7,
                                   dpe_size=83, noise_enabled=False)
        other = ServingEngine(params, HEANA, other_cfg, max_batch=1,
                              plan_cache=engine.plan_cache)
        other.warmup()                          # traces a new executable
        engine.infer(_images(key, 450, 1))
        assert engine.stats()["retraces_since_warmup"] == 0
        assert other.stats()["retraces_since_warmup"] == 0

    def test_infer_one(self, served):
        key, _, engine = served
        img = _images(key, 400, 1)[0]
        one = engine.infer_one(img)
        assert one.shape == (10,)
        np.testing.assert_array_equal(np.asarray(one),
                                      np.asarray(engine.infer(img[None])[0]))

    def test_stats_surface(self, served):
        key, _, engine = served
        engine.infer(_images(key, 500, 3))       # forces padding
        s = engine.stats()
        assert s["requests"] >= 1 and s["images"] >= 3
        assert s["padded_slots"] > 0 and 0 < s["padding_fraction"] < 1
        assert s["latency_p50_s"] <= s["latency_p99_s"]
        assert s["sustained_ips"] > 0
        assert s["warmed_up"] is True
        assert s["plan_cache"]["entries"] > 0
        assert s["compile_cache"]["entries"] > 0
        assert s["buckets"] == [1, 2, 4, 8]


class TestServingErrors:
    """The executor's clear errors surface through the serving entry
    points (ISSUE 4 satellite)."""

    def test_non_image_request(self, served):
        key, _, engine = served
        with pytest.raises(ValueError, match="images"):
            engine.infer(_images(key, 1, 2).reshape(2, -1))
        with pytest.raises(ValueError, match="H, W, C"):
            engine.infer_one(_images(key, 1, 1))

    def test_empty_request(self, served):
        _, _, engine = served
        with pytest.raises(ValueError, match="batch 0"):
            engine.infer(jnp.zeros((0, 16, 16, 3)))

    def test_mismatched_geometry_raises_clearly(self, served):
        """Engine planned for 16x16: an 8x8 request hits the executor's
        geometry validation with its row-count message."""
        key, _, engine = served
        bad = jax.random.normal(key, (2, 8, 8, 3))
        with pytest.raises(ValueError, match="rows"):
            engine.infer(bad)

    def test_batch_mismatch_error_names_serving_engine(self, served):
        """The raw executor's batch-mismatch error now points at the
        bucketing API as the fix."""
        key, params, engine = served
        x5 = _images(key, 2, 5)
        with pytest.raises(ValueError, match="ServingEngine"):
            execute_cnn(params, x5, engine.plans[8], _cfg())

    def test_noise_without_key_raises_through_serving(self):
        params = build_small_cnn(jax.random.PRNGKey(0))
        engine = ServingEngine(params, HEANA, _cfg(noise=True),
                               max_batch=2, plan_cache=PlanCache())
        with pytest.raises(ValueError, match="key"):
            engine.infer(jnp.ones((2, 16, 16, 3)))


class TestNoiseServing:
    def test_noisy_serving_reproducible_per_key(self):
        key = jax.random.PRNGKey(0)
        params = build_small_cnn(key)
        engine = ServingEngine(params, HEANA, _cfg(noise=True),
                               max_batch=2, plan_cache=PlanCache())
        engine.warmup()                        # dummy key pre-traces
        x = _images(key, 1, 2)
        before = trace_count()
        r1 = engine.infer(x, key=jax.random.PRNGKey(5))
        r2 = engine.infer(x, key=jax.random.PRNGKey(5))
        r3 = engine.infer(x, key=jax.random.PRNGKey(6))
        assert trace_count() == before         # serving keys reuse warmup
        np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
        assert not np.array_equal(np.asarray(r1), np.asarray(r3))


class TestThreadSafety:
    def test_concurrent_serving_bitwise_and_no_retrace(self, served):
        key, _, engine = served
        xs = [_images(key, 600 + i, (i % 8) + 1) for i in range(8)]
        expect = [np.asarray(engine.infer(x)) for x in xs]
        before = trace_count()
        results = [None] * len(xs)
        errors = []

        def worker(i):
            try:
                results[i] = np.asarray(engine.infer(xs[i]))
            except Exception as exc:  # noqa: BLE001
                errors.append(exc)

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(len(xs))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert errors == []
        assert trace_count() == before
        for got, want in zip(results, expect):
            np.testing.assert_array_equal(got, want)

    def test_compiled_forward_memo_safe_under_threads(self, served):
        """Hammer the executor wrapper memo from many threads: no
        corruption, bound respected, all callers get a working fn."""
        from repro.exec import executor as ex
        _, params, engine = served
        errors = []

        def worker(seed):
            try:
                for b in engine.buckets:
                    fn = ex.compiled_forward(engine.plans[b], _cfg())
                    assert callable(fn)
            except Exception as exc:  # noqa: BLE001
                errors.append(exc)

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(12)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert errors == []
        assert ex.compile_cache_stats()["entries"] <= \
            ex.compile_cache_stats()["max_entries"]


class TestMicroBatcher:
    def test_prefilled_batch_rows_match_batched_inference(self, served):
        """Plumbing contract: with the queue pre-filled to exactly one
        bucket, every Future gets its own row of the batched logits."""
        key, _, engine = served
        imgs = [_images(key, 700 + i, 1)[0] for i in range(8)]
        mb = MicroBatcher(engine, max_delay_s=0.05)
        futs = [mb.submit(im) for im in imgs]
        mb.start()
        outs = [f.result(timeout=120) for f in futs]
        mb.stop()
        ref = engine.infer(jnp.stack(imgs))
        for i, out in enumerate(outs):
            np.testing.assert_array_equal(np.asarray(out),
                                          np.asarray(ref[i]))
        s = mb.stats()
        assert s["batches_formed"] == 1 and s["requests_batched"] == 8
        assert s["mean_fill"] == 8.0

    def test_concurrent_submitters_all_resolve(self, served):
        key, _, engine = served
        with MicroBatcher(engine, max_delay_s=0.005) as mb:
            futs = []
            lock = threading.Lock()

            def submitter(tid):
                for i in range(3):
                    f = mb.submit(_images(key, 800 + 10 * tid + i, 1)[0])
                    with lock:
                        futs.append(f)

            threads = [threading.Thread(target=submitter, args=(t,))
                       for t in range(4)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            outs = [f.result(timeout=120) for f in futs]
        assert len(outs) == 12
        assert all(o.shape == (10,) for o in outs)

    def test_engine_errors_propagate_to_futures(self, served):
        """A bad request fails ITS future, not the worker thread."""
        key, _, engine = served
        with MicroBatcher(engine, max_delay_s=0.0) as mb:
            bad = mb.submit(jnp.zeros((8, 8, 3)))   # wrong geometry
            with pytest.raises(ValueError, match="rows"):
                bad.result(timeout=120)
            good = mb.submit(_images(key, 900, 1)[0])
            assert good.result(timeout=120).shape == (10,)

    def test_mixed_shape_batch_fails_futures_not_worker(self, served):
        """Two different image shapes coalesced into ONE batch make the
        stack fail: those futures error, the worker survives and keeps
        serving."""
        key, _, engine = served
        mb = MicroBatcher(engine, max_delay_s=0.2)
        good_img = _images(key, 910, 1)[0]
        f1 = mb.submit(good_img)
        f2 = mb.submit(jnp.zeros((8, 8, 3)))    # stacks against 16x16
        mb.start()
        with pytest.raises(ValueError):
            f1.result(timeout=120)
        with pytest.raises(ValueError):
            f2.result(timeout=120)
        f3 = mb.submit(good_img)                # worker is still alive
        assert f3.result(timeout=120).shape == (10,)
        mb.stop()

    def test_submit_after_stop_raises(self, served):
        _, _, engine = served
        mb = MicroBatcher(engine).start()
        mb.stop()
        with pytest.raises(RuntimeError, match="stopped"):
            mb.submit(jnp.zeros((16, 16, 3)))

    def test_noise_engine_requires_key(self):
        params = build_small_cnn(jax.random.PRNGKey(0))
        engine = ServingEngine(params, HEANA, _cfg(noise=True),
                               max_batch=2, plan_cache=PlanCache())
        with pytest.raises(ValueError, match="key"):
            MicroBatcher(engine)

    def test_validates_image_rank(self, served):
        _, _, engine = served
        with MicroBatcher(engine) as mb:
            with pytest.raises(ValueError, match="H, W, C"):
                mb.submit(jnp.zeros((1, 16, 16, 3)))


class TestDataParallel:
    def test_dp_requires_noise_off(self):
        params = build_small_cnn(jax.random.PRNGKey(0))
        with pytest.raises(ValueError, match="noise"):
            ServingEngine(params, HEANA, _cfg(noise=True), max_batch=4,
                          plan_cache=PlanCache(), data_parallel=True)

    @pytest.mark.skipif(len(jax.devices()) < 2,
                        reason="needs >= 2 devices (run under XLA_FLAGS="
                               "--xla_force_host_platform_device_count=4)")
    def test_dp_bitwise_equals_single_device(self, served):
        key, params, engine = served
        n_dev = len(jax.devices())
        if engine.max_bucket % n_dev:
            pytest.skip(f"max bucket {engine.max_bucket} not divisible "
                        f"by {n_dev} devices")
        dp = ServingEngine(params, HEANA, _cfg(), max_batch=8,
                           plan_cache=engine.plan_cache,
                           data_parallel=True)
        dp.warmup()
        x = _images(key, 950, 8)
        np.testing.assert_array_equal(
            np.asarray(jax.device_get(dp.infer(x))),
            np.asarray(jax.device_get(engine.infer(x))))
        assert dp.stats()["data_parallel"] is True


class TestGmean:
    def test_empty_suite_raises_clear_valueerror(self):
        with pytest.raises(ValueError, match="empty"):
            pm.gmean([])

    def test_nonempty_unchanged(self):
        assert pm.gmean([2.0, 8.0]) == pytest.approx(4.0)

"""Substrate tests: data pipeline, optimizer, checkpoint, fault tolerance,
gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.checkpoint import checkpoint as ckpt
from repro.data.pipeline import DataConfig, SyntheticLM, make_source
from repro.optim import compression as comp
from repro.optim import optimizer as opt
from repro.runtime import fault_tolerance as ft


class TestDataPipeline:
    def _cfg(self, **kw):
        base = dict(vocab_size=128, seq_len=32, global_batch=4, seed=7)
        base.update(kw)
        return DataConfig(**base)

    def test_deterministic_across_instances(self):
        a = SyntheticLM(self._cfg()).batch(5)
        b = SyntheticLM(self._cfg()).batch(5)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_steps_differ(self):
        src = SyntheticLM(self._cfg())
        assert not np.array_equal(src.batch(0)["tokens"],
                                  src.batch(1)["tokens"])

    def test_targets_shifted(self):
        src = SyntheticLM(self._cfg())
        b = src.batch(0)
        assert b["tokens"].shape == (4, 32)
        assert b["targets"].shape == (4, 32)

    def test_host_sharding_partition(self):
        cfg = self._cfg(global_batch=8)
        full_rows = []
        for h in range(4):
            full_rows.append(SyntheticLM(cfg).batch(3, h, 4)["tokens"])
        stacked = np.concatenate(full_rows)
        assert stacked.shape == (8, 32)
        # distinct hosts produce distinct rows
        assert len({r.tobytes() for r in stacked}) == 8

    def test_file_shards_roundtrip(self, tmp_path):
        arr = np.arange(10_000, dtype=np.int32) % 128
        np.save(tmp_path / "shard_000.npy", arr)
        cfg = self._cfg(source="file", path=str(tmp_path))
        src = make_source(cfg)
        b = src.batch(0)
        assert b["tokens"].shape == (4, 32)
        np.testing.assert_array_equal(b["targets"][:, :-1],
                                      b["tokens"][:, 1:])

    @given(step=st.integers(0, 1000), host=st.integers(0, 3))
    @settings(max_examples=10, deadline=None)
    def test_property_stateless_reproducibility(self, step, host):
        cfg = self._cfg(global_batch=8)
        a = SyntheticLM(cfg).batch(step, host, 4)
        b = SyntheticLM(cfg).batch(step, host, 4)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])


class TestOptimizer:
    def _setup(self):
        params = {"w": jnp.ones((4, 4), jnp.bfloat16),
                  "b": jnp.zeros((4,), jnp.bfloat16)}
        return params, opt.init(params)

    def test_descends_quadratic(self):
        cfg = opt.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                              total_steps=100)
        params, state = self._setup()
        loss = lambda p: jnp.sum(p["w"].astype(jnp.float32) ** 2)  # noqa
        l0 = float(loss(params))
        for _ in range(20):
            grads = jax.grad(loss)(params)
            params, state, _ = opt.apply(cfg, params, state, grads)
        assert float(loss(params)) < l0 * 0.5

    def test_warmup_and_decay(self):
        cfg = opt.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                              min_lr_ratio=0.1)
        lrs = [float(opt.lr_schedule(cfg, jnp.int32(s)))
               for s in (0, 5, 10, 100)]
        assert lrs[0] == 0.0
        assert 0.4 < lrs[1] < 0.6
        assert abs(lrs[2] - 1.0) < 1e-6
        assert abs(lrs[3] - 0.1) < 1e-6

    def test_grad_clip_bounds_update(self):
        cfg = opt.AdamWConfig(lr=1e-3, grad_clip=1.0, warmup_steps=0)
        params, state = self._setup()
        grads = jax.tree.map(lambda p: jnp.full(p.shape, 1e6, jnp.float32),
                             params)
        _, _, metrics = opt.apply(cfg, params, state, grads)
        assert float(metrics["grad_norm"]) > 1e5   # reported pre-clip


class TestCheckpoint:
    def _tree(self, seed=0):
        k = jax.random.PRNGKey(seed)
        return {"params": {"w": jax.random.normal(k, (8, 8))},
                "opt": {"m": jnp.zeros((8, 8))}}

    def test_roundtrip(self, tmp_path):
        root = str(tmp_path / "ck")
        tree = self._tree()
        ckpt.save(root, 10, tree, extra={"loss": 1.5})
        restored, manifest = ckpt.restore(root, self._tree(seed=1))
        np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                      np.asarray(tree["params"]["w"]))
        assert manifest["step"] == 10 and manifest["extra"]["loss"] == 1.5

    def test_atomicity_no_tmp_visible(self, tmp_path):
        root = str(tmp_path / "ck")
        ckpt.save(root, 1, self._tree())
        assert ckpt.latest_step(root) == 1
        # a stale .tmp dir must not count as a checkpoint
        os.makedirs(os.path.join(root, "step_00000099.tmp"))
        assert ckpt.latest_step(root) == 1

    def test_checksum_detects_corruption(self, tmp_path):
        root = str(tmp_path / "ck")
        path = ckpt.save(root, 2, self._tree())
        npz = os.path.join(path, "arrays.npz")
        data = dict(np.load(npz))
        key = list(data.keys())[0]
        data[key] = data[key] + 1.0
        np.savez(npz, **data)
        with pytest.raises(IOError):
            ckpt.restore(root, self._tree())

    def test_retention_keeps_last_and_pinned(self, tmp_path):
        root = str(tmp_path / "ck")
        for s in (1, 2, 3, 4, 5):
            ckpt.save(root, s, self._tree())
        ckpt.retain(root, keep_last=2, pin_step=1)
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(root))
        assert steps == [1, 4, 5]


class TestFaultTolerance:
    def test_heartbeat_detects_dead_host(self):
        t = [0.0]
        mon = ft.HeartbeatMonitor(["a", "b"], dead_after=10,
                                  clock=lambda: t[0])
        t[0] = 5.0
        mon.beat("a")
        t[0] = 12.0
        assert mon.dead_hosts() == ["b"]

    def test_straggler_flagging(self):
        pol = ft.StragglerPolicy(tolerance=3.0, strikes_to_flag=3)
        for step in range(10):
            for h in ("h0", "h1", "h2", "h3"):
                pol.record(h, 1.0 if h != "h3" else 10.0)
            flagged = pol.update_strikes()
        assert flagged == ["h3"]

    def test_elastic_remesh_preserves_model_axis(self):
        plan = ft.plan_elastic_remesh(500, model_axis=16)
        assert plan.model == 16 and plan.data == 31
        assert plan.dropped_devices == 4
        with pytest.raises(RuntimeError):
            ft.plan_elastic_remesh(8, model_axis=16)

    def test_resilient_loop_survives_failures(self):
        log = {"saved": 0, "fail_at": {7, 23}}
        state = {"ckpt": 0}

        def step_fn(s):
            if s in log["fail_at"]:
                log["fail_at"].remove(s)
                raise RuntimeError("chip lost")

        def save_fn(s):
            state["ckpt"] = s
            log["saved"] += 1

        rep = ft.run_resilient_loop(step_fn, save_fn,
                                    lambda: state["ckpt"], total_steps=30,
                                    checkpoint_every=5)
        assert rep.final_step == 30
        assert rep.failures_survived == 2 and rep.restores == 2

    def test_resilient_loop_gives_up_eventually(self):
        def always_fail(s):
            raise RuntimeError("dead rack")
        with pytest.raises(RuntimeError):
            ft.run_resilient_loop(always_fail, lambda s: None, lambda: 0,
                                  total_steps=5, max_failures=3)


class TestGradCompression:
    def _grads(self, seed=0):
        k = jax.random.PRNGKey(seed)
        return {"a": jax.random.normal(k, (64, 64)),
                "b": jax.random.normal(jax.random.fold_in(k, 1), (64,))}

    def test_roundtrip_error_bounded(self):
        g = self._grads()
        state = comp.init_state(g)
        cg, state = comp.compress_grads(g, state)
        dg = comp.decompress_grads(cg)
        for key in g:
            scale = float(jnp.max(jnp.abs(g[key]))) / 127.0
            assert float(jnp.max(jnp.abs(dg[key] - g[key]))) <= scale * 0.51

    def test_error_feedback_carries_residual(self):
        g = self._grads()
        state = comp.init_state(g)
        _, state = comp.compress_grads(g, state)
        res_norm = float(opt.global_norm(state.residual))
        assert res_norm > 0.0
        # next round compensates: mean of decompressed over 2 rounds closer
        cg2, _ = comp.compress_grads(g, state)
        dg2 = comp.decompress_grads(cg2)
        # residual-corrected second round differs from the first
        assert not np.allclose(np.asarray(dg2["a"]),
                               np.asarray(comp.decompress_grads(
                                   comp.compress_grads(
                                       g, comp.init_state(g))[0])["a"]))

    def test_allreduce_compressed_under_shard_map(self):
        devs = jax.devices()
        if len(devs) < 1:
            pytest.skip("no devices")
        mesh = jax.make_mesh((1,), ("pod",))
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        g = self._grads()
        state = comp.init_state(g)

        def f(g, r):
            return comp.allreduce_compressed(
                g, comp.ErrorFeedbackState(r), "pod")[0]

        out = shard_map(f, mesh=mesh, in_specs=(P(), P()), out_specs=P(),
                        check_rep=False)(g, state.residual)
        for key in g:
            scale = float(jnp.max(jnp.abs(g[key]))) / 127.0
            np.testing.assert_allclose(np.asarray(out[key]),
                                       np.asarray(g[key]),
                                       atol=scale * 0.51)

    def test_compression_ratio(self):
        g = self._grads()
        assert comp.compression_ratio(g) > 3.9

"""Benchmark-layer unit tests: paper-claim assertions + parser/probe logic.

(The heavy probe compiles run in benchmarks.roofline out-of-band; here we
test the logic that doesn't need a 512-device mesh.)
"""
import dataclasses

import pytest

from repro.configs import SHAPES, get_config
from repro.launch.dryrun import collective_bytes


class TestFig9Bench:
    def test_anchor_row(self):
        import benchmarks.fig9_scalability as f9
        rows = {r.name: r.derived for r in f9.run()}
        assert rows["fig9/anchors_within_1"] == "9/9"
        assert rows["fig9/heana/b4/dr1"] == 83


class TestFig1Bench:
    def test_orderings(self):
        import benchmarks.fig1_buffer_accesses as f1
        rows = {r.name: r.derived for r in f1.run()}
        assert rows["fig1/ws_min_weight_reads"] == 1
        assert rows["fig1/is_min_input_reads"] == 1
        assert rows["fig1/bpca/is/psum"] == 0      # BPCA kills psum traffic
        assert rows["fig1/nobpca/is/psum"] > 0


class TestFig11Bench:
    def test_paper_headline_claims(self):
        import benchmarks.fig11_fps as f11
        rows = {r.name: r.derived for r in f11.run(batches=(1,),
                                                   drs=(1.0,))}
        # abstract: >=66x FPS (gmean, equal area) vs both baselines
        assert rows["fig11/fps/heana_os_vs_amw/dr1"] >= 66
        assert rows["fig11/fps/heana_os_vs_maw/dr1"] >= 66
        # FPS/W within 25% of the calibration anchor (89x/84x)
        assert rows["fig11/fpsw/heana_os_vs_amw/dr1"] >= 0.75 * 89
        assert rows["fig11/fpsw/heana_os_vs_maw/dr1"] >= 0.75 * 84


class TestFig5Bench:
    def test_trends(self):
        import benchmarks.fig5_taom_accuracy as f5
        rows = {r.name: r.derived for r in f5.run()}
        # accuracy rises with optical power at fixed rate
        assert rows["fig5/accuracy_bits/p10dbm/dr1"] > \
            rows["fig5/accuracy_bits/p-20dbm/dr1"]
        # accuracy falls with data rate at fixed power
        assert rows["fig5/accuracy_bits/p-10dbm/dr1"] > \
            rows["fig5/accuracy_bits/p-10dbm/dr10"]
        # precision (ENOB) rises with power
        assert rows["fig5/precision_enob/p10dbm/dr1"] > \
            rows["fig5/precision_enob/p-20dbm/dr1"]


class TestCollectiveParser:
    HLO = """
  %ag = bf16[256,1024] all-gather(bf16[16,1024] %x), dimensions={0}
  %ar = f32[1024,1024] all-reduce(f32[1024,1024] %y), to_apply=%sum
  %rs = f32[64,1024] reduce-scatter(f32[1024,1024] %z), dimensions={0}
  %cp = f32[8,8] collective-permute(f32[8,8] %w), source_target_pairs={{0,1}}
  %dot = f32[128,128] dot(f32[128,64] %a, f32[64,128] %b)
"""

    def test_bytes_and_counts(self):
        out = collective_bytes(self.HLO)
        assert out["bytes"]["all-gather"] == 256 * 1024 * 2
        assert out["bytes"]["all-reduce"] == 1024 * 1024 * 4
        assert out["bytes"]["reduce-scatter"] == 64 * 1024 * 4
        assert out["bytes"]["collective-permute"] == 8 * 8 * 4
        assert out["counts"]["all-gather"] == 1
        assert out["total_bytes"] == sum(out["bytes"].values())

    def test_ignores_non_collectives(self):
        out = collective_bytes("%dot = f32[128,128] dot(%a, %b)")
        assert out["total_bytes"] == 0

    def test_async_start_counted_once(self):
        hlo = """
  %ags = (bf16[16,8], bf16[32,8]) all-gather-start(bf16[16,8] %x)
  %agd = bf16[32,8] all-gather-done((bf16[16,8], bf16[32,8]) %ags)
"""
        out = collective_bytes(hlo)
        assert out["counts"]["all-gather"] == 1


class TestProbePlans:
    def test_single_group_family(self):
        from benchmarks.roofline import cfg_with_repeats, probe_plan
        cfg = get_config("mamba2-130m")
        full, probes = probe_plan(cfg)
        assert full == {"mamba": 24}
        assert probes == [{"mamba": 1}, {"mamba": 2}]
        assert cfg_with_repeats(cfg, {"mamba": 2}).num_layers == 2

    def test_moe_two_groups(self):
        from benchmarks.roofline import cfg_with_repeats, probe_plan
        cfg = get_config("deepseek-v3-671b")
        full, probes = probe_plan(cfg)
        assert full == {"dense_head": 3, "moe_body": 58}
        c = cfg_with_repeats(cfg, {"dense_head": 1, "moe_body": 2})
        assert c.num_layers == 3 and c.moe.first_dense_layers == 1

    def test_hybrid_tail(self):
        from benchmarks.roofline import group_repeats, cfg_with_repeats
        cfg = get_config("zamba2-7b")
        assert group_repeats(cfg) == {"hybrid": 13, "tail": 3}
        c = cfg_with_repeats(cfg, {"hybrid": 1, "tail": 3})
        assert c.num_layers == 6 + 3

    def test_audio_groups(self):
        from benchmarks.roofline import cfg_with_repeats, group_repeats
        cfg = get_config("whisper-tiny")
        assert group_repeats(cfg) == {"enc": 4, "dec": 4}
        c = cfg_with_repeats(cfg, {"enc": 2, "dec": 1})
        assert c.encoder_layers == 2 and c.num_layers == 1

    def test_localglobal_period(self):
        from benchmarks.roofline import cfg_with_repeats, group_repeats
        cfg = get_config("gemma3-12b")
        assert group_repeats(cfg) == {"localglobal": 8}
        assert cfg_with_repeats(cfg, {"localglobal": 2}).num_layers == 12


class TestModelFlops:
    def test_dense_param_count_close_to_nameplate(self):
        from benchmarks.roofline import param_counts
        total, active = param_counts(get_config("qwen2-0.5b"))
        # non-embedding params of qwen2-0.5b ~= 0.36B
        assert 0.25e9 < total < 0.5e9
        assert total == active

    def test_moe_active_much_smaller_than_total(self):
        from benchmarks.roofline import param_counts
        total, active = param_counts(get_config("deepseek-v3-671b"))
        assert 5.0e11 < total < 8.0e11          # ~671B nameplate
        assert active < 0.1 * total              # top-8 of 256 experts

    def test_flops_shapes(self):
        from benchmarks.roofline import model_flops, param_counts
        cfg = get_config("qwen2-1.5b")
        _, active = param_counts(cfg)
        t = SHAPES["train_4k"]
        assert model_flops(cfg, t) == pytest.approx(
            6 * active * t.global_batch * t.seq_len)
        d = SHAPES["decode_32k"]
        assert model_flops(cfg, d) == pytest.approx(
            2 * active * d.global_batch)


class TestTable4Bench:
    def test_heana_drop_small(self):
        import benchmarks.table4_accuracy as t4
        rows = {r.name: r.derived for r in t4.run()}
        assert rows["table4/top1/exact"] >= 0.6      # task learned
        # paper claim: ~0.1% drop at 8-bit; proxy tolerance: within the
        # +-1% sampling error of the 512-example eval
        assert abs(rows["table4/top1_drop_pct/heana"]) <= 1.5

"""Executed-trace energy accounting + kernel/plan coherence (ISSUE 5).

The tentpole contract: constructing a pipeline from a single
core.hw.OperatingPoint yields a kernel config, plan, and energy model
that agree by construction —

  * executed-trace FPS and FPS/W (hw.trace_energy over the executed
    plan) equal the analytic perf_model.cnn_inference prediction at the
    same per-layer dataflows, for every zoo network;
  * a PhotonicConfig whose bits/DPE geometry disagrees with the plan's
    hardware is REJECTED with an actionable error, through both
    execute_cnn and ServingEngine (satellite bugfix — it used to execute
    without complaint and silently mis-report modeled numbers);
  * per-layer energy for resnet_mini at the default operating point is
    pinned to tests/golden/resnet_mini_energy.json (tolerance-based,
    analogous to the golden latency trace);
  * plan v4: plans embed the operating point, persisted pre-v4 cache
    entries cleanly invalidate on load, and serving stats gain
    joules-per-inference / sustained watts.
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hw
from repro.core import perf_model as pm
from repro.core.types import Backend, Dataflow, PhotonicConfig
from repro.exec import (PlanCache, ServingEngine, execute_cnn,
                        execution_summary, plan_for_network, schedule_cnn)
from repro.exec import plan_cache as pc
from repro.models.cnn import build_small_cnn, lowered_gemms
from repro.models.zoo_cnn import ZOO

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "resnet_mini_energy.json")

OP = hw.OperatingPoint.equal_area("heana", Dataflow.OS, 1.0,
                                  noise_enabled=False)


def _setup(name="resnet_mini", batch=2, seed=0, op=OP):
    model = ZOO[name]
    params = model.init_params(jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(seed), 1),
                          (batch, *model.in_hw, model.in_ch))
    plan = plan_for_network(params, op, batch=batch, in_hw=model.in_hw,
                            lowering=model.graph, cache=PlanCache())
    return model, params, x, plan


class TestExecutedTraceCoherence:
    """Acceptance: executed-trace energy/FPS == analytic prediction, by
    construction, for all four paper networks (+ the small CNN)."""

    @pytest.mark.parametrize("name", list(ZOO))
    def test_trace_energy_matches_cnn_inference(self, name):
        model, params, x, plan = _setup(name)
        res = execute_cnn(params, x, plan, OP.kernel_config(),
                          impl="ref", lowering=model.graph)
        executed = res.energy()
        analytic = pm.cnn_inference(model.gemms(params), plan.acc,
                                    batch=2, dataflows=list(plan.dataflows))
        assert executed.fps == pytest.approx(analytic.fps, rel=1e-9)
        assert executed.fps_per_watt == pytest.approx(
            analytic.fps_per_watt, rel=1e-9)
        assert executed.energy_j == pytest.approx(analytic.energy_j,
                                                  rel=1e-9)
        assert executed.latency_s == pytest.approx(analytic.latency_s,
                                                   rel=1e-9)

    def test_plan_embeds_operating_point(self):
        _, _, _, plan = _setup()
        assert plan.op == OP
        assert plan.acc == OP.accelerator_config()

    def test_non_default_optics_stay_coherent(self):
        """Review regression: an OperatingPoint with non-default optics
        (different laser power -> different link budget, sigma AND laser
        energy) must still satisfy executed == modeled — schedule_cnn
        threads the op's optics into the plan result, trace_energy into
        the executed side."""
        from repro.core.types import OpticalParams
        hot = dataclasses.replace(
            OP, optics=dataclasses.replace(OpticalParams(),
                                           p_laser_dbm=13.0))
        model, params, x, plan = _setup("small_cnn", op=hot)
        res = execute_cnn(params, x, plan, hot.kernel_config(),
                          impl="ref", lowering=model.graph)
        executed = res.energy()
        # plan totals and executed trace agree (both at the op's optics)
        assert executed.fps_per_watt == pytest.approx(
            plan.result.fps_per_watt, rel=1e-9)
        assert executed.energy_j == pytest.approx(plan.result.energy_j,
                                                  rel=1e-9)
        # ...and both differ from the default-optics figures (the laser
        # term doubled): the optics knob is genuinely live.
        default_plan = _setup("small_cnn")[3]
        assert executed.energy_j > default_plan.result.energy_j
        # analytic cross-check at the same optics closes the loop
        ana = pm.cnn_inference(model.gemms(params), plan.acc, batch=2,
                               dataflows=list(plan.dataflows),
                               optics=hot.optics)
        assert executed.energy_j == pytest.approx(ana.energy_j, rel=1e-9)

    def test_traces_carry_executed_energy(self):
        model, params, x, plan = _setup()
        res = execute_cnn(params, x, plan, OP.kernel_config(),
                          impl="ref", lowering=model.graph)
        for t, p in zip(res.traces, plan.layers):
            assert t.executed_energy_j > 0
            assert t.n_chunks == p.tile.n_chunks
            assert t.adc_conversions > 0
            # modeled (plan) and executed energy agree per layer too
            assert t.executed_energy_j == pytest.approx(p.energy_j,
                                                        rel=1e-9)
        # per-layer sum + static share == total
        assert sum(t.executed_energy_j for t in res.traces) + \
            res.energy().breakdown.static == \
            pytest.approx(res.executed_energy_j, rel=1e-12)

    def test_energy_does_not_sync_fingerprints(self):
        """ExecutionResult.energy() is host-side plan accounting — it
        must not materialize the traces (the serving no-sync contract)."""
        model, params, x, plan = _setup()
        res = execute_cnn(params, x, plan, OP.kernel_config(),
                          impl="ref", lowering=model.graph)
        assert res.energy().energy_j > 0
        assert res._traces is None

    def test_execution_summary_reports_energy(self):
        model, params, x, plan = _setup()
        res = execute_cnn(params, x, plan, OP.kernel_config(),
                          impl="ref", lowering=model.graph)
        s = execution_summary(res, "resnet_mini")
        assert s["executed_energy_j"] == pytest.approx(
            res.executed_energy_j)
        assert s["operating_point"]["dpe_size"] == OP.n
        assert set(s["energy_breakdown"]) == {
            "laser", "dac", "adc", "tuning", "buffer", "reduction",
            "static"}
        assert all(l["executed_energy_j"] > 0 for l in s["layers"])


class TestKernelPlanCoherenceErrors:
    """Satellite bugfix: incoherent cfg/plan pairs raise, through both
    entry points, with an actionable message."""

    def test_execute_cnn_rejects_wrong_bits_with_op_plan(self):
        model, params, x, plan = _setup()
        bad = OP.kernel_config(bits=6)
        with pytest.raises(ValueError, match="DIFFERENT hardware"):
            execute_cnn(params, x, plan, bad, impl="ref",
                        lowering=model.graph)

    def test_execute_cnn_rejects_wrong_dpe_geometry(self):
        model, params, x, plan = _setup()
        bad = OP.kernel_config(dpe_size=64)
        with pytest.raises(ValueError, match=r"N=64.*N=83|DPE size"):
            execute_cnn(params, x, plan, bad, impl="ref",
                        lowering=model.graph)

    def test_execute_cnn_rejects_wrong_backend_and_rate(self):
        model, params, x, plan = _setup()
        with pytest.raises(ValueError, match="backend"):
            execute_cnn(params, x, plan,
                        OP.kernel_config(backend=Backend.AMW),
                        impl="ref", lowering=model.graph)
        with pytest.raises(ValueError, match="data rate"):
            execute_cnn(params, x, plan,
                        OP.kernel_config(data_rate_gsps=5.0),
                        impl="ref", lowering=model.graph)

    def test_error_message_names_the_fix(self):
        model, params, x, plan = _setup()
        with pytest.raises(ValueError, match="OperatingPoint"):
            execute_cnn(params, x, plan, OP.kernel_config(bits=6),
                        impl="ref", lowering=model.graph)

    def test_legacy_plan_checks_geometry_only(self):
        """Plans scheduled from a bare AcceleratorConfig can't pin bits
        (no operating point) — but geometry is still enforced."""
        model = ZOO["small_cnn"]
        params = model.init_params(jax.random.PRNGKey(0))
        x = jnp.zeros((2, *model.in_hw, model.in_ch))
        acc = pm.AcceleratorConfig.equal_area("heana", Dataflow.OS, 1.0)
        plan = plan_for_network(params, acc, batch=2, in_hw=model.in_hw,
                                lowering=model.graph, cache=PlanCache())
        assert plan.op is None
        # historical bits-6 usage keeps working...
        cfg6 = PhotonicConfig(backend=Backend.HEANA, bits=6, dpe_size=83,
                              noise_enabled=False)
        execute_cnn(params, x, plan, cfg6, impl="ref",
                    lowering=model.graph)
        # ...but a DPE-size mismatch is now caught
        bad = PhotonicConfig(backend=Backend.HEANA, bits=6, dpe_size=128,
                             noise_enabled=False)
        with pytest.raises(ValueError, match="DPE size"):
            execute_cnn(params, x, plan, bad, impl="ref",
                        lowering=model.graph)

    def test_exact_backend_exempt(self):
        model, params, x, plan = _setup("small_cnn")
        cfg = PhotonicConfig(backend=Backend.EXACT, noise_enabled=False)
        res = execute_cnn(params, x, plan, cfg, impl="ref",
                          lowering=model.graph)
        assert res.logits.shape[0] == 2

    def test_serving_engine_rejects_incoherent_cfg_at_construction(self):
        params = build_small_cnn(jax.random.PRNGKey(0))
        with pytest.raises(ValueError, match="DIFFERENT hardware"):
            ServingEngine(params,
                          dataclasses.replace(OP, dataflow=Dataflow.OS),
                          OP.kernel_config(bits=6), max_batch=2)

    def test_serving_engine_derives_cfg_from_operating_point(self):
        params = build_small_cnn(jax.random.PRNGKey(0))
        engine = ServingEngine(params, OP, max_batch=2)
        assert engine._cfg == OP.kernel_config()
        out = engine.infer(jnp.zeros((1, 16, 16, 3)))
        assert out.shape == (1, 10)

    def test_serving_engine_requires_cfg_for_legacy_acc(self):
        params = build_small_cnn(jax.random.PRNGKey(0))
        acc = pm.AcceleratorConfig.equal_area("heana", Dataflow.OS, 1.0)
        with pytest.raises(ValueError, match="cfg is required"):
            ServingEngine(params, acc, max_batch=2)


class TestServingEnergyStats:
    def test_joules_per_inference_and_sustained_watts(self):
        params = build_small_cnn(jax.random.PRNGKey(0))
        engine = ServingEngine(params, OP, max_batch=4)
        s0 = engine.stats()
        assert s0["modeled_energy_j"] == 0.0
        engine.infer(jnp.zeros((3, 16, 16, 3)))   # pads to bucket 4
        engine.infer(jnp.zeros((1, 16, 16, 3)))   # bucket 1
        s = engine.stats()
        e4 = hw.trace_energy(engine.plans[4])
        e1 = hw.trace_energy(engine.plans[1])
        assert s["modeled_energy_j"] == pytest.approx(
            e4.energy_j + e1.energy_j, rel=1e-12)
        assert s["modeled_j_per_image"] == pytest.approx(
            s["modeled_energy_j"] / 4, rel=1e-12)   # 4 real images
        assert s["modeled_sustained_w"] == pytest.approx(
            s["modeled_energy_j"] / (e4.latency_s + e1.latency_s),
            rel=1e-12)


class TestGoldenEnergyTrace:
    """Checked-in per-layer energies for the default operating point:
    silent changes to the event accounting (dataflow schedules, Table 3
    constants, DAC/ADC policy) fail here."""

    def _compute(self):
        model, params, x, plan = _setup("resnet_mini", batch=2, seed=0)
        res = execute_cnn(params, x, plan, OP.kernel_config(),
                          impl="ref", lowering=model.graph)
        return res, res.energy()

    def test_golden_energy_matches(self):
        with open(GOLDEN) as fh:
            golden = json.load(fh)
        res, te = self._compute()
        assert [t.name for t in res.traces] == golden["layers"]
        np.testing.assert_allclose(
            [t.executed_energy_j for t in res.traces],
            golden["per_layer_energy_j"], rtol=1e-6,
            err_msg="per-layer energy drifted from the checked-in golden "
                    "trace — if the change is intentional, regenerate "
                    "tests/golden/resnet_mini_energy.json")
        assert [t.adc_conversions for t in res.traces] == \
            golden["adc_conversions"]
        np.testing.assert_allclose(te.energy_j, golden["total_energy_j"],
                                   rtol=1e-6)
        np.testing.assert_allclose(te.fps_per_watt,
                                   golden["fps_per_watt"], rtol=1e-6)
        gop = golden["operating_point"]
        assert (OP.n, OP.n_dpus, OP.bits) == \
            (gop["dpe_size"], gop["n_dpus"], gop["bits"])


class TestPlanV4Cache:
    def test_persisted_entries_stamped_with_version(self, tmp_path):
        cache = PlanCache()
        gemms = lowered_gemms(build_small_cnn(jax.random.PRNGKey(0)))
        schedule_cnn(gemms, OP, batch=1, cache=cache)
        path = str(tmp_path / "plans.json")
        cache.dump(path)
        with open(path) as fh:
            entries = json.load(fh)
        assert entries and all(
            v["plan_version"] == pc.PLAN_FORMAT_VERSION
            for v in entries.values())
        fresh = PlanCache()
        assert fresh.load(path) == len(entries)

    def test_pre_v4_entries_cleanly_invalidate_on_load(self, tmp_path):
        cache = PlanCache()
        gemms = lowered_gemms(build_small_cnn(jax.random.PRNGKey(0)))
        schedule_cnn(gemms, OP, batch=1, cache=cache)
        path = str(tmp_path / "plans.json")
        cache.dump(path)
        with open(path) as fh:
            entries = json.load(fh)
        # simulate a v3-era dump: no version stamp at all
        for v in entries.values():
            del v["plan_version"]
        with open(path, "w") as fh:
            json.dump(entries, fh)
        fresh = PlanCache()
        with pytest.warns(RuntimeWarning, match="older plan format"):
            assert fresh.load(path) == 0
        assert len(fresh) == 0

    def test_cached_plan_compares_equal_including_op(self):
        cache = PlanCache()
        gemms = lowered_gemms(build_small_cnn(jax.random.PRNGKey(0)))
        p1 = schedule_cnn(gemms, OP, batch=1, cache=cache)
        p2 = schedule_cnn(gemms, OP, batch=1, cache=cache)
        assert p2.cache_misses == 0
        assert p1 == p2 and hash(p1) == hash(p2)
        # an op-less plan of the same hardware is a DIFFERENT plan
        p3 = schedule_cnn(gemms, OP.accelerator_config(), batch=1,
                          cache=cache)
        assert p3 != p1

"""Dataflow accounting (Fig. 1) + system perf model (Figs. 11-14) tests."""
import math

import pytest

from repro.core import dataflow as df
from repro.core import perf_model as pm
from repro.core.types import BPCA_NUM_CAPACITORS, Dataflow
from repro.models import cnn

G = df.GemmShape(c=784, k=864, d=128)   # GoogleNet inception-3a 3x3


class TestBufferAccessCounting:
    def test_fig1_orderings(self):
        """WS minimizes weight reads, IS input reads, OS psum traffic."""
        t = df.fig1_table(G, dpe_size=83, with_bpca=False)
        assert t["ws"]["weight_reads"] == min(x["weight_reads"]
                                              for x in t.values())
        assert t["is"]["input_reads"] == min(x["input_reads"]
                                             for x in t.values())
        assert t["os"]["psum_accesses"] == 0
        assert t["is"]["psum_accesses"] > 0 and t["ws"]["psum_accesses"] > 0

    def test_exact_counts(self):
        acc = df.buffer_accesses(G, Dataflow.WS, 83, with_bpca=False)
        assert acc.weight_reads == G.k * G.d
        assert acc.input_reads == G.c * G.k * G.d
        f = math.ceil(G.k / 83)
        assert acc.psum_writes == G.c * G.d * f
        assert df.buffer_accesses(G, Dataflow.IS, 83, False).input_reads == \
            G.c * G.k

    def test_bpca_eliminates_psum_traffic(self):
        for flow in Dataflow:
            acc = df.buffer_accesses(G, flow, 83, with_bpca=True)
            assert acc.psum_writes == 0 and acc.psum_reads == 0

    def test_googlenet_layer5_identity(self):
        l5 = cnn.googlenet_layer5()
        assert (l5.c, l5.k, l5.d) == (784, 864, 128)


class TestSchedule:
    def test_cycle_count_conservation(self):
        """Total (output, fold) work is dataflow-invariant."""
        f = math.ceil(G.k / 83)
        work = G.c * G.d * f
        for flow in Dataflow:
            sch = df.schedule(G, flow, 83, 83, with_bpca=True, os_speedup=1)
            assert sch.cycles == math.ceil(work / 83)

    def test_os_speedup_reduces_cycles(self):
        base = df.schedule(G, Dataflow.OS, 83, 83, True, os_speedup=1)
        fast = df.schedule(G, Dataflow.OS, 83, 83, True, os_speedup=10)
        assert fast.cycles == math.ceil(base.cycles / 10)
        # speedup only applies to OS
        ws1 = df.schedule(G, Dataflow.WS, 83, 83, True, os_speedup=10)
        ws2 = df.schedule(G, Dataflow.WS, 83, 83, True, os_speedup=1)
        assert ws1.cycles == ws2.cycles

    def test_capacitor_spill(self):
        big = df.GemmShape(c=BPCA_NUM_CAPACITORS * 3, k=256, d=64)
        sch = df.schedule(big, Dataflow.WS, 83, 83, with_bpca=True)
        assert sch.psum_events > 0          # in-flight outputs exceed p=4608
        sch_os = df.schedule(big, Dataflow.OS, 83, 83, with_bpca=True)
        assert sch_os.psum_events == 0      # OS never spills

    def test_without_bpca_every_fold_roundtrips(self):
        sch = df.schedule(G, Dataflow.WS, 83, 83, with_bpca=False)
        f = math.ceil(G.k / 83)
        assert sch.psum_events == G.outputs * (f - 1)
        assert sch.adc_conversions == G.outputs * f


class TestCnnTables:
    @pytest.mark.parametrize("name,gmacs_lo,gmacs_hi", [
        ("googlenet", 1.4, 1.8), ("resnet50", 3.5, 4.2),
        ("mobilenet_v2", 0.25, 0.35), ("shufflenet_v2", 0.10, 0.20),
    ])
    def test_total_macs_match_literature(self, name, gmacs_lo, gmacs_hi):
        layers = cnn.CNN_ZOO[name]()
        gmacs = cnn.total_macs(layers) / 1e9
        assert gmacs_lo < gmacs < gmacs_hi


class TestPerfModel:
    @pytest.mark.parametrize("dr", [1.0, 5.0, 10.0])
    def test_heana_os_beats_all_baselines(self, dr):
        layers = cnn.CNN_ZOO["googlenet"]()
        h = pm.cnn_inference(
            layers, pm.AcceleratorConfig.equal_area("heana", Dataflow.OS, dr))
        for be in ("amw", "maw"):
            for flow in Dataflow:
                b = pm.cnn_inference(
                    layers, pm.AcceleratorConfig.equal_area(be, flow, dr))
                assert h.fps > b.fps
                assert h.fps_per_watt > b.fps_per_watt

    def test_paper_headline_gmean_ratios_at_1gsps(self):
        """Abstract: >=66x FPS and >=84x FPS/W on gmean (equal area).

        Our model reproduces the FPS claim with margin and lands within
        ~25% of the FPS/W anchor it was calibrated against (DESIGN.md §6).
        """
        ratios_fps, ratios_w = [], []
        for name, fn in cnn.CNN_ZOO.items():
            layers = fn()
            h = pm.cnn_inference(layers, pm.AcceleratorConfig.equal_area(
                "heana", Dataflow.OS, 1.0))
            for be in ("amw", "maw"):
                best_fps = max(pm.cnn_inference(
                    layers, pm.AcceleratorConfig.equal_area(be, f, 1.0)).fps
                    for f in Dataflow)
                best_w = max(pm.cnn_inference(
                    layers, pm.AcceleratorConfig.equal_area(
                        be, f, 1.0)).fps_per_watt for f in Dataflow)
                ratios_fps.append(h.fps / best_fps)
                ratios_w.append(h.fps_per_watt / best_w)
        assert pm.gmean(ratios_fps) >= 66.0
        assert pm.gmean(ratios_w) >= 0.75 * 84.0

    def test_ws_best_dataflow_for_thermo_optic_baselines(self):
        layers = cnn.CNN_ZOO["resnet50"]()
        for be in ("amw", "maw"):
            fps = {f: pm.cnn_inference(
                layers, pm.AcceleratorConfig.equal_area(be, f, 1.0)).fps
                for f in Dataflow}
            assert fps[Dataflow.WS] > fps[Dataflow.OS]
            assert fps[Dataflow.WS] > fps[Dataflow.IS]

    def test_os_best_dataflow_for_heana(self):
        # OS dominates on every CNN (paper §6.3); the WS-vs-IS order is
        # shape dependent in our model (WS spills the capacitor bank when a
        # layer's C exceeds p=4608, e.g. early ResNet50 layers).
        for name, fn in cnn.CNN_ZOO.items():
            layers = fn()
            fps = {f: pm.cnn_inference(
                layers, pm.AcceleratorConfig.equal_area("heana", f, 1.0)).fps
                for f in Dataflow}
            assert fps[Dataflow.OS] > fps[Dataflow.WS], name
            assert fps[Dataflow.OS] > fps[Dataflow.IS], name

    def test_bpca_integration_helps_baselines(self):
        layers = cnn.CNN_ZOO["mobilenet_v2"]()
        for base, upg in (("amw", "amw_bpca"), ("maw", "maw_bpca")):
            for flow in Dataflow:
                b = pm.cnn_inference(
                    layers, pm.AcceleratorConfig.equal_area(base, flow, 1.0))
                u = pm.cnn_inference(
                    layers, pm.AcceleratorConfig.equal_area(upg, flow, 1.0))
                assert u.fps >= b.fps
                assert u.energy_j <= b.energy_j

    def test_batch_amortizes_weight_loads(self):
        layers = cnn.CNN_ZOO["shufflenet_v2"]()
        acc = pm.AcceleratorConfig.equal_area("amw", Dataflow.WS, 1.0)
        b1 = pm.cnn_inference(layers, acc, batch=1)
        b256 = pm.cnn_inference(layers, acc, batch=256)
        assert b256.fps > 2 * b1.fps   # tuning amortized over the batch

    def test_energy_breakdown_positive_and_consistent(self):
        layers = cnn.CNN_ZOO["googlenet"]()
        r = pm.cnn_inference(layers, pm.AcceleratorConfig.equal_area(
            "heana", Dataflow.OS, 1.0))
        b = r.breakdown
        parts = [b.laser, b.dac, b.adc, b.tuning, b.buffer, b.reduction,
                 b.static]
        assert all(p >= 0 for p in parts)
        assert abs(sum(parts) - r.energy_j) < 1e-12 + 1e-6 * r.energy_j

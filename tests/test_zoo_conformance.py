"""Differential conformance suite for the executable model zoo (ISSUE 3).

For EVERY zoo network (the four paper-CNN reduced variants + the small
CNN):

  * the compiled Pallas executor output is BIT-EXACT vs the pure-jnp
    oracle (kernels/ref.py via reference_forward) with noise off;
  * warm compiled calls never retrace (trace_count pins it per model);
  * the runnable graph's GEMM table equals the paper-style analytic
    accounting (models.cnn._conv/_dw formulas — what feeds
    benchmarks/fig11_fps.py) layer by layer, so modeled MACs and
    executed MACs come from one source of truth;
  * golden-trace regression: per-layer fingerprints for a fixed seed on
    resnet_mini are checked in — a kernel/scheduler refactor that
    silently changes numerics fails loudly.

Plus the explicit spatial-validation contract (the old `_spatial_dims`/
pooling code assumed even square dims and failed with reshape noise).
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import perf_model as pm
from repro.core.types import Backend, Dataflow, PhotonicConfig
from repro.exec import (PlanCache, execute_cnn, graph_summary,
                        plan_for_network, reference_forward, trace_count)
from repro.models import cnn, lowering as lw
from repro.models.zoo_cnn import PAPER_ZOO, ZOO

HEANA = pm.AcceleratorConfig.equal_area("heana", Dataflow.OS, 1.0)
GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")


def _cfg(noise=False):
    # bits=6 keeps every partial sum < 2^24 — exact float accumulation,
    # the precondition of the bit-exactness contract (see test_exec).
    return PhotonicConfig(backend=Backend.HEANA, bits=6, dpe_size=83,
                          noise_enabled=noise)


def _setup(model, batch=2, seed=0):
    params = model.init_params(jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(seed), 1),
                          (batch, *model.in_hw, model.in_ch))
    plan = plan_for_network(params, HEANA, batch=batch, in_hw=model.in_hw,
                            lowering=model.graph, cache=PlanCache())
    return params, x, plan


class TestZooConformance:
    """Acceptance: all four paper-CNN reduced variants execute end-to-end
    through the compiled path, bit-exact vs the reference oracle."""

    @pytest.mark.parametrize("name", list(ZOO))
    def test_compiled_pallas_bit_exact_vs_oracle(self, name):
        model = ZOO[name]
        params, x, plan = _setup(model)
        res = execute_cnn(params, x, plan, _cfg(), impl="pallas",
                          lowering=model.graph)
        ref = reference_forward(params, x, _cfg(), lowering=model.graph)
        np.testing.assert_array_equal(np.asarray(res.logits),
                                      np.asarray(ref))
        assert res.logits.shape == (2, model.num_classes)

    @pytest.mark.parametrize("name", list(ZOO))
    def test_zero_warm_retraces(self, name):
        model = ZOO[name]
        params, x, plan = _setup(model)
        execute_cnn(params, x, plan, _cfg(), lowering=model.graph)  # cold
        before = trace_count()
        for _ in range(3):
            execute_cnn(params, x, plan, _cfg(), lowering=model.graph)
        assert trace_count() == before
        # an equal replanned plan must hit the same executable
        plan2 = plan_for_network(params, HEANA, batch=2,
                                 in_hw=model.in_hw, lowering=model.graph,
                                 cache=PlanCache())
        execute_cnn(params, x, plan2, _cfg(), lowering=model.graph)
        assert trace_count() == before

    @pytest.mark.parametrize("name", list(ZOO))
    def test_lowered_matches_direct_conv_reference(self, name):
        """The im2col/block-diagonal lowering == jax.lax.conv numerics
        (exact matmul, no photonic pipeline)."""
        model = ZOO[name]
        params = model.init_params(jax.random.PRNGKey(3))
        x = jax.random.normal(jax.random.PRNGKey(4),
                              (2, *model.in_hw, model.in_ch))
        got = lw.graph_apply(params, x, model.graph)
        want = lw.direct_forward(params, x, model.graph)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=1e-6)

    @pytest.mark.parametrize("name", list(ZOO))
    def test_traces_cover_every_gemm_layer(self, name):
        model = ZOO[name]
        params, x, plan = _setup(model)
        res = execute_cnn(params, x, plan, _cfg(), impl="ref",
                          lowering=model.graph)
        want = [n.name for n in model.graph.gemm_nodes]
        assert [t.name for t in res.traces] == want
        assert all(t.latency_s > 0 for t in res.traces)

    def test_depthwise_traces_report_executed_fused_gemm(self):
        """LayerTrace is 'what actually ran': depthwise layers trace the
        fused block-diagonal (M, kk*kk*C, C) GEMM — consistent with the
        tile the scheduler sized — not the analytic per-group shape."""
        model = ZOO["mobilenet_mini"]
        params, x, plan = _setup(model)
        res = execute_cnn(params, x, plan, _cfg(), impl="ref",
                          lowering=model.graph)
        trace = {t.name: t for t in res.traces}["ir2_dw"]
        lplan = {p.name: p for p in plan.layers}["ir2_dw"]
        assert lplan.count == 96 and lplan.d == 1 and lplan.k == 9
        assert trace.k == 9 * 96 and trace.d == 96    # executed dims
        assert trace.m == lplan.c                     # rows unchanged
        assert trace.block_d == lplan.tile.block_d    # tile fits D=96

    def test_paper_zoo_is_the_four_evaluation_networks(self):
        assert set(PAPER_ZOO) == {"resnet_mini", "mobilenet_mini",
                                  "shufflenet_mini", "googlenet_mini"}
        # each keeps its structural signature
        ops = {n: graph_summary(ZOO[n].graph)["ops"] for n in PAPER_ZOO}
        assert ops["resnet_mini"]["residual_add"] == 3
        assert ops["mobilenet_mini"]["depthwise_conv"] == 3
        assert ops["mobilenet_mini"]["residual_add"] == 1
        assert ops["shufflenet_mini"]["shuffle"] == 2
        assert ops["shufflenet_mini"]["slice"] == 2
        assert ops["shufflenet_mini"]["concat"] == 2
        assert ops["googlenet_mini"]["concat"] == 1


class TestAnalyticConsistency:
    """The runnable lowering and the paper-table accounting (the
    _conv/_dw formulas behind benchmarks/fig11_fps.py's CNN_ZOO tables)
    agree layer by layer — one source of truth."""

    @pytest.mark.parametrize("name", list(ZOO))
    def test_graph_gemms_equal_analytic_tables(self, name):
        model = ZOO[name]
        assert model.gemms() == model.analytic()

    @pytest.mark.parametrize("name", list(ZOO))
    def test_macs_match_and_params_validate(self, name):
        model = ZOO[name]
        analytic_macs = sum(g.macs for g in model.analytic())
        runnable_macs = sum(g.macs for g in model.gemms())
        assert analytic_macs == runnable_macs > 0
        # weight-shape validation path: gemms(params) must agree too
        params = model.init_params(jax.random.PRNGKey(0))
        assert model.gemms(params) == model.analytic()

    @pytest.mark.parametrize("name", list(PAPER_ZOO))
    def test_mini_blocks_mirror_full_tables(self, name):
        """Structural cross-check against the full-size fig11 tables:
        the reduced variant exercises the same layer *kinds* (depthwise
        presence, 1x1/3x3/5x5 kernels) as its full network."""
        full = cnn.CNN_ZOO[name.replace("_mini", "").replace(
            "resnet", "resnet50").replace("mobilenet", "mobilenet_v2")
            .replace("shufflenet", "shufflenet_v2")]()
        mini = ZOO[name].gemms()
        full_has_dw = any(g.count > 1 for g in full)
        mini_has_dw = any(g.count > 1 for g in mini)
        assert full_has_dw == mini_has_dw
        assert mini[0].k == 27          # mini stems are 3x3 on RGB
        assert mini[-1].c == 1          # both end in a classifier fc
        assert full[-1].c == 1


class TestGoldenTrace:
    """Checked-in per-layer fingerprints for a fixed seed: refactors of
    the kernel/scheduler/lowering that silently change numerics fail."""

    PATH = os.path.join(GOLDEN_DIR, "resnet_mini_trace.json")

    def _compute(self):
        model = ZOO["resnet_mini"]
        params, x, plan = _setup(model, batch=2, seed=0)
        res = execute_cnn(params, x, plan, _cfg(), impl="pallas",
                          lowering=model.graph)
        fp = [float(v) for v in np.asarray(res.fingerprints)]
        return {
            "model": "resnet_mini",
            "seed": 0,
            "batch": 2,
            "bits": 6,
            "layers": [n.name for n in model.graph.gemm_nodes],
            "fingerprints": fp,
            "logits_mean_abs": float(np.mean(np.abs(
                np.asarray(res.logits)))),
        }

    def test_golden_fingerprints_match(self):
        with open(self.PATH) as fh:
            golden = json.load(fh)
        got = self._compute()
        assert got["layers"] == golden["layers"]
        np.testing.assert_allclose(
            got["fingerprints"], golden["fingerprints"], rtol=1e-5,
            err_msg="per-layer numerics drifted from the checked-in "
                    "golden trace — if the change is intentional, "
                    "regenerate tests/golden/resnet_mini_trace.json")
        np.testing.assert_allclose(got["logits_mean_abs"],
                                   golden["logits_mean_abs"], rtol=1e-5)


class TestSpatialValidation:
    """Satellite bugfix: `_spatial_dims`/pooling used to assume even
    square dims — stride-2 and odd-dimension handling is now explicit."""

    def test_spatial_dims_validates_spec(self):
        assert cnn._spatial_dims(16) == (16, 16)
        assert cnn._spatial_dims((16, 8)) == (16, 8)
        with pytest.raises(ValueError, match=r"\(H, W\) pair"):
            cnn._spatial_dims((16,))
        with pytest.raises(ValueError, match=r"\(H, W\) pair"):
            cnn._spatial_dims((16, 8, 3))
        with pytest.raises(ValueError, match="positive"):
            cnn._spatial_dims(0)
        with pytest.raises(ValueError, match="positive"):
            cnn._spatial_dims((16, -8))

    def test_stride2_conv_handles_odd_dims_explicitly(self):
        """SAME-padded stride-2 convs on odd/rect inputs are first-class
        (out = ceil(in/2)) — no even-dims assumption."""
        g = lw.OpGraph((lw.input_node(2),
                        lw.conv("c", "input", 4, stride=2),
                        lw.pool("gap", "c", kind="global"),
                        lw.fc("out", "gap", 3)))
        params = lw.init_params(g, jax.random.PRNGKey(0), in_hw=(15, 9))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 15, 9, 2))
        got = lw.graph_apply(params, x, g)
        want = lw.direct_forward(params, x, g)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=1e-6)
        shapes = lw.infer_shapes(g, (15, 9))
        assert shapes["c"] == (8, 5, 4)

    def test_valid_pool_on_indivisible_dims_raises_clearly(self):
        g = lw.OpGraph((lw.input_node(3),
                        lw.conv("c", "input", 4),
                        lw.pool("p", "c"),
                        lw.fc("out", "p", 2)))
        with pytest.raises(ValueError, match="does not tile H=15"):
            lw.infer_shapes(g, (15, 8))
        with pytest.raises(ValueError, match="does not tile W=9"):
            lw.infer_shapes(g, (16, 9))
        # 'same' pooling is the documented escape hatch
        g2 = lw.OpGraph((lw.input_node(3),
                         lw.conv("c", "input", 4),
                         lw.pool("p", "c", padding="same"),
                         lw.fc("out", "p", 2)))
        assert lw.infer_shapes(g2, (15, 9))["p"] == (8, 5, 4)

    def test_valid_window_larger_than_input_raises(self):
        with pytest.raises(ValueError, match="does not fit"):
            lw.conv_out_dim(2, 3, 1, "valid")

    def test_same_avg_pool_rejected_as_ambiguous(self):
        with pytest.raises(ValueError, match="ambiguous"):
            lw.OpGraph((lw.input_node(3),
                        lw.pool("p", "input", kind="avg",
                                padding="same")))

    def test_graph_structural_validation(self):
        with pytest.raises(ValueError, match="topologically"):
            lw.OpGraph((lw.input_node(3), lw.conv("a", "missing", 4)))
        with pytest.raises(ValueError, match="duplicate"):
            lw.OpGraph((lw.input_node(3), lw.conv("a", "input", 4),
                        lw.conv("a", "input", 4)))
        with pytest.raises(ValueError, match="first node"):
            lw.OpGraph((lw.input_node(3), lw.input_node(3, name="in2")))
        with pytest.raises(ValueError, match="2 input"):
            lw.OpGraph((lw.input_node(3),
                        lw.OpNode("r", "residual_add", ("input",))))

    def test_residual_shape_mismatch_raises_clearly(self):
        g = lw.OpGraph((lw.input_node(3),
                        lw.conv("a", "input", 4),
                        lw.conv("b", "input", 8),
                        lw.residual("r", "a", "b"),
                        lw.fc("out", "r", 2)))
        with pytest.raises(ValueError, match="disagree"):
            lw.infer_shapes(g, 8)

    def test_executor_rejects_wrong_geometry_with_clear_errors(self):
        model = ZOO["googlenet_mini"]
        params, x, plan = _setup(model)
        bad = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 16, 3))
        with pytest.raises(ValueError, match="rows"):
            execute_cnn(params, bad, plan, _cfg(), lowering=model.graph)
        with pytest.raises(ValueError, match="images"):
            execute_cnn(params, x.reshape(2, -1), plan, _cfg(),
                        lowering=model.graph)

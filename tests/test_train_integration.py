"""End-to-end training integration: loss goes down, checkpoints restore
bit-exactly, and the resilient loop survives injected failures."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.configs import get_config
from repro.data.pipeline import DataConfig, make_source
from repro.launch.train import train
from repro.models import model_zoo as zoo
from repro.optim import optimizer as opt
from repro.runtime import fault_tolerance as ft


class TestTrainDriver:
    def test_loss_decreases(self, tmp_path):
        res = train("qwen2-0.5b", smoke=True, steps=30, batch=4, seq=32,
                    lr=1e-3, ckpt_dir=str(tmp_path / "ck"), ckpt_every=10)
        assert res.final_loss < res.first_loss

    def test_resume_is_exact(self, tmp_path):
        # uninterrupted 20-step run
        r_full = train("mamba2-130m", smoke=True, steps=20, batch=4, seq=32,
                       ckpt_dir=str(tmp_path / "a"), ckpt_every=10)
        # "crash" after 10 steps, then resume to 20 — same final loss
        d = str(tmp_path / "b")
        train("mamba2-130m", smoke=True, steps=10, batch=4, seq=32,
              ckpt_dir=d, ckpt_every=10, total_steps=20)
        r_resumed = train("mamba2-130m", smoke=True, steps=20, batch=4,
                          seq=32, ckpt_dir=d, ckpt_every=10, resume=True)
        assert r_resumed.steps == 10
        np.testing.assert_allclose(r_resumed.final_loss, r_full.final_loss,
                                   rtol=1e-6)

    def test_photonic_qat_numerics_path(self, tmp_path):
        res = train("qwen2-0.5b", smoke=True, steps=8, batch=2, seq=16,
                    numerics="photonic_heana")
        assert np.isfinite(res.final_loss)


class TestResilientTrainingLoop:
    def test_crash_restore_reproduces_exact_state(self, tmp_path):
        """A supervised loop with injected failures lands on the same
        params as an uninterrupted run (deterministic pipeline + atomic
        checkpoints)."""
        cfg = get_config("qwen2-0.5b", smoke=True)
        adam = opt.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=24)
        data = make_source(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                      global_batch=2, seed=3))

        @jax.jit
        def step_fn(params, state, tokens, targets):
            loss, grads = jax.value_and_grad(
                lambda p: zoo.loss_fn(p, {"tokens": tokens,
                                          "targets": targets}, cfg))(params)
            params, state, _ = opt.apply(adam, params, state, grads)
            return params, state, loss

        def run(fail_at, root):
            params = zoo.init_params(cfg, jax.random.PRNGKey(0))
            state = opt.init(params)
            holder = {"params": params, "state": state}
            ckpt.save(root, 0, (params, state))

            def do_step(s):
                if s in fail_at:
                    fail_at.remove(s)
                    raise RuntimeError("injected failure")
                b = data.batch(s)
                p, st, _ = step_fn(holder["params"], holder["state"],
                                   jnp.asarray(b["tokens"]),
                                   jnp.asarray(b["targets"]))
                holder["params"], holder["state"] = p, st

            def save(s):
                ckpt.save(root, s, (holder["params"], holder["state"]))

            def restore():
                s = ckpt.latest_step(root)
                (holder["params"], holder["state"]), _ = ckpt.restore(
                    root, (holder["params"], holder["state"]))
                return s

            rep = ft.run_resilient_loop(do_step, save, restore,
                                        total_steps=12, checkpoint_every=4)
            return holder["params"], rep

        p_clean, rep_clean = run(set(), str(tmp_path / "a"))
        p_faulty, rep_faulty = run({3, 9}, str(tmp_path / "b"))
        assert rep_clean.failures_survived == 0
        assert rep_faulty.failures_survived == 2
        for a, b in zip(jax.tree.leaves(p_clean), jax.tree.leaves(p_faulty)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_straggler_plus_remesh_plan(self):
        """Flag a straggler, then plan the shrunken mesh without it."""
        pol = ft.StragglerPolicy(strikes_to_flag=2)
        hosts = [f"h{i}" for i in range(8)]   # 8 hosts x 64 chips
        for _ in range(6):
            for h in hosts:
                pol.record(h, 1.0 if h != "h5" else 9.0)
            flagged = pol.update_strikes()
        assert flagged == ["h5"]
        surviving_chips = (len(hosts) - len(flagged)) * 64
        plan = ft.plan_elastic_remesh(surviving_chips, model_axis=16)
        assert plan.model == 16 and plan.data == 28
        assert plan.devices == 448

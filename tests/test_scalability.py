"""Scalability analysis (paper Eqs. 1-3, Fig. 9, Table 2) tests."""
import math

import pytest

from repro.core import noise as noise_mod
from repro.core import scalability
from repro.core.types import OpticalParams


class TestNoiseModel:
    def test_enob_monotonic_in_power(self):
        o = OpticalParams()
        bits = [noise_mod.enob(p, 1.0, o) for p in (-30, -20, -10, 0, 10)]
        assert all(b2 > b1 for b1, b2 in zip(bits, bits[1:]))

    def test_enob_decreases_with_data_rate(self):
        o = OpticalParams()
        assert noise_mod.enob(-10, 1.0, o) > noise_mod.enob(-10, 5.0, o) \
            > noise_mod.enob(-10, 10.0, o)

    def test_p_pd_opt_inverts_enob(self):
        o = OpticalParams()
        feasible = 0
        for bits in (2, 4, 6, 8):
            for dr in (1.0, 5.0, 10.0):
                try:
                    p = noise_mod.p_pd_opt_dbm(bits, dr, o)
                except ValueError:
                    # RIN-limited: SNR saturates with power, so high bits at
                    # high data rates are physically unreachable (paper
                    # Fig. 9 shows the same cliff).
                    assert bits >= 7
                    continue
                feasible += 1
                assert abs(noise_mod.enob(p, dr, o) - bits) < 1e-3
        assert feasible >= 9

    def test_rin_cliff_infeasible_returns_zero_n(self):
        assert scalability.max_dpe_size("amw", 8, 10.0) == 0

    def test_paper_operating_point_power(self):
        # Hand calc (DESIGN.md): thermal-dominated noise => ~-18 dBm for
        # 4-bit ENOB at 1 GS/s.
        p = noise_mod.p_pd_opt_dbm(4, 1.0, OpticalParams())
        assert -19.0 < p < -17.0


class TestLinkBudget:
    def test_output_power_decreases_with_n(self):
        o = OpticalParams()
        powers = [scalability.output_power_dbm(n, n, 1.8, o) for n in
                  (1, 8, 64, 256)]
        assert all(p2 < p1 for p1, p2 in zip(powers, powers[1:]))

    def test_heana_penalty_advantage(self):
        o = OpticalParams()
        ph = scalability.output_power_dbm(50, 50, 1.8, o, obl_passes=1)
        pa = scalability.output_power_dbm(50, 50, 5.8, o, obl_passes=2)
        assert ph > pa


class TestFig9Anchors:
    """Paper Fig. 9 / Table 2 anchor points at 4-bit precision."""

    @pytest.mark.parametrize("backend,expected", [
        ("heana", (83, 42, 30)),
        ("amw", (36, 17, 12)),
        ("maw", (43, 22, 15)),   # paper: (43, 21, 15); 5 GS/s off-by-one
    ])
    def test_4bit_anchors(self, backend, expected):
        got = tuple(scalability.max_dpe_size(backend, 4, dr)
                    for dr in (1.0, 5.0, 10.0))
        assert got == expected

    def test_heana_dominates_all_cells(self):
        for b in range(1, 9):
            for dr in (1.0, 5.0, 10.0):
                nh = scalability.max_dpe_size("heana", b, dr)
                na = scalability.max_dpe_size("amw", b, dr)
                nm = scalability.max_dpe_size("maw", b, dr)
                assert nh >= nm >= na

    def test_n_decreases_with_bits_and_rate(self):
        ns_b = [scalability.max_dpe_size("heana", b, 1.0) for b in range(1, 9)]
        assert all(n1 >= n2 for n1, n2 in zip(ns_b, ns_b[1:]))
        ns_dr = [scalability.max_dpe_size("heana", 4, dr)
                 for dr in (1.0, 5.0, 10.0)]
        assert all(n1 >= n2 for n1, n2 in zip(ns_dr, ns_dr[1:]))

    def test_bpca_suffix_equivalent(self):
        assert scalability.max_dpe_size("amw_bpca", 4, 1.0) == \
            scalability.max_dpe_size("amw", 4, 1.0)


class TestTable2:
    def test_table2_lookup(self):
        assert scalability.table2_dpu_config("heana", 1.0) == (83, 52)
        assert scalability.table2_dpu_config("amw", 10.0) == (12, 1950)
        assert scalability.table2_dpu_config("maw_bpca", 5.0) == (21, 1100)

"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + no NaNs (assignment deliverable f)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import SHAPES, get_config, list_archs, cell_is_supported
from repro.models import model_zoo as zoo
from repro.models.layers import PhotonicCtx
from repro.core.types import Backend, PhotonicConfig

ARCHS = list_archs()


def _batch(cfg, b=2, s=16, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (b, s), 0, cfg.vocab_size),
        "targets": jax.random.randint(ks[1], (b, s), 0, cfg.vocab_size),
    }
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            ks[2], (b, cfg.encoder_seq, zoo.WHISPER_FRAME_FEAT),
            jnp.float32).astype(jnp.dtype(cfg.dtype))
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            ks[2], (b, cfg.num_image_tokens, cfg.vision_embed_dim),
            jnp.float32).astype(jnp.dtype(cfg.dtype))
    return batch


def test_all_ten_archs_registered():
    assert len(ARCHS) == 10
    expected = {"qwen2-0.5b", "qwen2-1.5b", "h2o-danube-3-4b", "gemma3-12b",
                "deepseek-v2-236b", "deepseek-v3-671b", "mamba2-130m",
                "whisper-tiny", "llava-next-mistral-7b", "zamba2-7b"}
    assert set(ARCHS) == expected


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_exact_numbers(arch):
    cfg = get_config(arch)
    expected = {
        "qwen2-0.5b": (24, 896, 14, 2, 4864, 151936),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
        "h2o-danube-3-4b": (24, 3840, 32, 8, 10240, 32000),
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "deepseek-v2-236b": (60, 5120, 128, 128, 12288, 102400),
        "deepseek-v3-671b": (61, 7168, 128, 128, 18432, 129280),
        "mamba2-130m": (24, 768, 0, 0, 0, 50280),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected
    if arch == "deepseek-v2-236b":
        assert (cfg.moe.num_experts, cfg.moe.experts_per_token,
                cfg.moe.num_shared_experts, cfg.moe.d_ff_expert,
                cfg.mla.kv_lora_rank) == (160, 6, 2, 1536, 512)
    if arch == "deepseek-v3-671b":
        assert (cfg.moe.num_experts, cfg.moe.experts_per_token,
                cfg.moe.num_shared_experts, cfg.moe.d_ff_expert) == \
            (256, 8, 1, 2048)
    if arch == "mamba2-130m":
        assert cfg.ssm.state_dim == 128
    if arch == "zamba2-7b":
        assert cfg.ssm.state_dim == 64
    if arch == "gemma3-12b":
        assert cfg.local_global_period == 6  # 5 local : 1 global


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    """One forward + one SGD train step on the reduced config."""
    cfg = get_config(arch, smoke=True)
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)

    loss, grads = jax.value_and_grad(zoo.loss_fn)(params, batch, cfg)
    assert jnp.isfinite(loss), arch
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32))))
               for g in flat), arch
    # one SGD step changes the loss
    new_params = jax.tree.map(lambda p, g: p - 0.05 * g.astype(p.dtype),
                              params, grads)
    loss2 = zoo.loss_fn(new_params, batch, cfg)
    assert jnp.isfinite(loss2)
    assert float(loss2) != float(loss)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_serve_roundtrip(arch):
    """prefill -> decode_step matches the teacher-forced forward."""
    cfg = get_config(arch, smoke=True)
    cfg = type(cfg)(**{**cfg.__dict__, "dtype": "float32"})
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 16
    batch = _batch(cfg, b, s)
    caches = zoo.init_caches(cfg, b, s + 8, jnp.float32)
    logits, state = zoo.prefill_fn(params, batch, cfg, caches)
    assert logits.shape == (b, 1, cfg.vocab_size)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    logits2, state = zoo.decode_fn(params, tok, jnp.int32(s), cfg, state)
    assert logits2.shape == (b, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2)))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_photonic_numerics_backend(arch):
    """The paper's technique runs as the numerics backend of every arch."""
    cfg = get_config(arch, smoke=True)
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    pctx = PhotonicCtx(cfg=PhotonicConfig(backend=Backend.HEANA, bits=8,
                                          adc_bits=12, dpe_size=64,
                                          noise_enabled=False), impl="ref")
    loss_exact = zoo.loss_fn(params, batch, cfg)
    loss_phot = zoo.loss_fn(params, batch, cfg, ctx=pctx)
    assert jnp.isfinite(loss_phot), arch
    # 8-bit noiseless photonic numerics stay close to exact
    assert abs(float(loss_phot) - float(loss_exact)) < \
        0.75 * abs(float(loss_exact)) + 0.5, arch


def test_deepseek_v3_mtp_head():
    """DeepSeek-V3's multi-token-prediction auxiliary head trains."""
    cfg = get_config("deepseek-v3-671b", smoke=True)
    assert cfg.mtp_depth == 1
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    assert "mtp" in params
    batch = _batch(cfg)
    base = zoo.loss_fn(params, batch, cfg)
    with_mtp, grads = jax.value_and_grad(
        lambda p: zoo.loss_fn(p, batch, cfg, mtp_weight=0.3))(params)
    assert float(with_mtp) > float(base)          # aux loss added
    assert any(float(jnp.max(jnp.abs(g))) > 0
               for g in jax.tree.leaves(grads["mtp"]))


def test_long_500k_support_flags():
    runs = {a: cell_is_supported(get_config(a), SHAPES["long_500k"])[0]
            for a in ARCHS}
    assert runs == {
        "qwen2-0.5b": False, "qwen2-1.5b": False, "h2o-danube-3-4b": True,
        "gemma3-12b": True, "deepseek-v2-236b": False,
        "deepseek-v3-671b": False, "mamba2-130m": True,
        "whisper-tiny": False, "llava-next-mistral-7b": False,
        "zamba2-7b": True,
    }

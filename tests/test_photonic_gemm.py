"""Photonic GEMM numerics tests (paper C1/C3) + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (Backend, PhotonicConfig, device_level_dot,
                        photonic_dot_general, quantize)
from repro.core.photonic_gemm import (design_point, detection_sigma,
                                      noise_shape, num_chunks, sample_noise)

KEY = jax.random.PRNGKey(0)


def _rand(shape, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


class TestQuantize:
    def test_roundtrip_error_bounded(self):
        x = _rand((64, 32))
        for bits in (2, 4, 8):
            q, s = quantize(x, bits)
            assert float(jnp.max(jnp.abs(q * s - x))) <= float(s) * 0.5 + 1e-6

    def test_integer_valued(self):
        q, _ = quantize(_rand((16, 16)), 4)
        assert jnp.allclose(q, jnp.round(q))
        assert float(jnp.max(jnp.abs(q))) <= 15

    def test_per_channel_axis(self):
        x = _rand((32, 8)) * jnp.arange(1, 9)[None, :]
        q, s = quantize(x, 8, axis=0)
        assert s.shape == (1, 8)
        np.testing.assert_allclose(np.asarray(q * s), np.asarray(x),
                                   atol=float(jnp.max(s)) * 0.5 + 1e-6)

    @given(bits=st.integers(2, 8), seed=st.integers(0, 2**16))
    @settings(max_examples=25, deadline=None)
    def test_property_scale_positive_and_bounded(self, bits, seed):
        x = _rand((8, 8), seed)
        q, s = quantize(x, bits)
        qmax = (1 << bits) - 1
        assert float(s) > 0
        assert float(jnp.max(jnp.abs(q))) <= qmax


class TestAccuracyHierarchy:
    """HEANA's single-ADC analog carry must not be worse than per-chunk ADC."""

    def _relerr(self, out, exact):
        return float(jnp.sqrt(jnp.mean((out - exact) ** 2)) /
                     jnp.sqrt(jnp.mean(exact ** 2)))

    def test_noiseless_heana_close_to_int_quant(self):
        x, w = _rand((8, 256), 1), _rand((256, 32), 2)
        exact = x @ w
        e_int = self._relerr(photonic_dot_general(
            x, w, PhotonicConfig(backend=Backend.INT_QUANT, bits=8,
                                 noise_enabled=False)), exact)
        e_heana = self._relerr(photonic_dot_general(
            x, w, PhotonicConfig(backend=Backend.HEANA, bits=8, adc_bits=12,
                                 noise_enabled=False)), exact)
        assert e_heana <= e_int * 1.5 + 1e-3

    def test_design_point_ordering_4bit(self):
        x, w = _rand((16, 512), 3), _rand((512, 64), 4)
        exact = x @ w
        errs = {}
        for be in (Backend.HEANA, Backend.AMW, Backend.MAW):
            cfg = design_point(be, 4, 1.0, adc_bits=8)
            outs = [photonic_dot_general(x, w, cfg, key=jax.random.PRNGKey(s))
                    for s in range(5)]
            errs[be] = np.mean([self._relerr(o, exact) for o in outs])
        assert errs[Backend.HEANA] < errs[Backend.AMW]
        assert errs[Backend.HEANA] < errs[Backend.MAW]

    def test_noise_reproducible_with_same_key(self):
        x, w = _rand((4, 200), 5), _rand((200, 16), 6)
        cfg = design_point(Backend.HEANA, 4, 1.0)
        a = photonic_dot_general(x, w, cfg, key=jax.random.PRNGKey(7))
        b = photonic_dot_general(x, w, cfg, key=jax.random.PRNGKey(7))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_higher_power_lower_noise(self):
        cfg_lo = PhotonicConfig(backend=Backend.HEANA, pd_power_dbm=-20.0)
        cfg_hi = PhotonicConfig(backend=Backend.HEANA, pd_power_dbm=0.0)
        assert detection_sigma(cfg_hi) < detection_sigma(cfg_lo)


class TestDeviceLevelEquivalence:
    """Fused einsum path == explicit TAOM->BPCA device path (no noise)."""

    @pytest.mark.parametrize("k,d,dpe", [(64, 8, 16), (83, 7, 83),
                                         (300, 16, 83), (100, 4, 7)])
    def test_equivalence(self, k, d, dpe):
        x, w = _rand((4, k), k), _rand((k, d), d)
        cfg = PhotonicConfig(backend=Backend.HEANA, bits=6, adc_bits=10,
                             dpe_size=dpe, noise_enabled=False)
        fused = photonic_dot_general(x, w, cfg)
        device = device_level_dot(x, w, cfg)
        np.testing.assert_allclose(np.asarray(fused), np.asarray(device),
                                   rtol=1e-5, atol=1e-5)


class TestSTE:
    def test_gradients_match_exact_matmul(self):
        x, w = _rand((4, 96), 8), _rand((96, 12), 9)
        cfg = PhotonicConfig(backend=Backend.HEANA, noise_enabled=False)

        def photonic_loss(x, w):
            return jnp.sum(photonic_dot_general(x, w, cfg) ** 2)

        gx, gw = jax.grad(photonic_loss, argnums=(0, 1))(x, w)
        # STE: gradient direction comes from the exact matmul with the
        # *simulated* output as cotangent source.
        out = photonic_dot_general(x, w, cfg)
        np.testing.assert_allclose(np.asarray(gx), np.asarray(2 * out @ w.T),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(gw), np.asarray(x.T @ (2 * out)),
                                   rtol=1e-4, atol=1e-4)

    def test_jit_and_vmap(self):
        x, w = _rand((3, 5, 64), 10), _rand((64, 8), 11)
        cfg = PhotonicConfig(backend=Backend.HEANA, noise_enabled=False)
        f = jax.jit(lambda x: photonic_dot_general(x, w, cfg))
        out = f(x)
        assert out.shape == (3, 5, 8)
        # jit fusion may flip a rounding decision exactly at a quantizer
        # boundary; outputs must agree to within one ADC step.
        eager = photonic_dot_general(x, w, cfg)
        adc_step = 2 * float(jnp.max(jnp.abs(eager))) / ((1 << cfg.adc_bits) - 1)
        np.testing.assert_allclose(np.asarray(out), np.asarray(eager),
                                   atol=adc_step * 1.05)
        # vmap runs (note: per-tensor activation scales are intentionally
        # per-vmapped-element, so values differ from the batched call).
        vm = jax.vmap(lambda xi: photonic_dot_general(xi, w, cfg))(x)
        assert vm.shape == out.shape and bool(jnp.all(jnp.isfinite(vm)))


class TestNoiseShapes:
    @given(k=st.integers(1, 400), d=st.integers(1, 16),
           dpe=st.integers(1, 128))
    @settings(max_examples=30, deadline=None)
    def test_property_noise_shape_consistency(self, k, d, dpe):
        for be in (Backend.HEANA, Backend.AMW):
            cfg = PhotonicConfig(backend=be, dpe_size=dpe)
            shp = noise_shape((2, k), (k, d), cfg)
            n = sample_noise(KEY, (2, k), (k, d), cfg)
            assert n.shape == shp
            if be == Backend.AMW:
                assert shp == (2, num_chunks(k, cfg), d)
            else:
                assert shp == (2, d)

    def test_chunking_matches_ceil(self):
        cfg = PhotonicConfig(dpe_size=83)
        assert num_chunks(83, cfg) == 1
        assert num_chunks(84, cfg) == 2
        assert num_chunks(1, cfg) == 1

"""Throughput-benchmark harness tests (benchmarks/throughput.py).

Pins the CI contract: the compiled serving path cannot silently regress
to eager/retracing — measure() must report zero warm retraces, bit-exact
compiled-vs-eager logits, and well-formed summaries for the report layer.
Batch 1 only: this is a harness test, the full grid (incl. the 5x floor
at batch 256) runs as `python -m benchmarks.throughput` / in run.py.
"""
import pytest

from benchmarks import throughput


@pytest.fixture(scope="module")
def measured():
    rows, summaries, failures = throughput.measure(batches=(1,), save=False)
    return rows, summaries, failures


class TestThroughputBench:
    def test_no_hard_failures(self, measured):
        _, _, failures = measured
        assert failures == []

    def test_no_warm_retraces_and_bitexact(self, measured):
        _, summaries, _ = measured
        (s,) = summaries
        assert s["retraces_warm"] == 0
        assert s["bitexact"] is True

    def test_compiled_beats_eager(self, measured):
        """Even at batch 1 the compiled path must win by a wide margin —
        eager pays per-call retracing of every Pallas grid step."""
        _, summaries, _ = measured
        (s,) = summaries
        assert s["speedup"] > throughput.SMOKE_MIN_SPEEDUP

    def test_rows_and_summary_shape(self, measured):
        rows, summaries, _ = measured
        names = [r.name for r in rows]
        assert "throughput/small_cnn/b1/compiled_ips" in names
        assert "throughput/small_cnn/b1/eager_ips" in names
        assert "throughput/no_retrace_warm" in names
        (s,) = summaries
        assert s["kind"] == "throughput" and s["batch"] == 1
        assert s["compiled_ips"] > 0 and s["eager_ips"] > 0
        assert s["modeled_fps"] > 0

"""Attention-layer unit tests: masks, windows, MLA, rolling caches."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MLAConfig
from repro.models import attention as A
from repro.models import layers as L

F32 = jnp.float32


def _spec(**kw):
    base = dict(d_model=48, num_heads=4, num_kv_heads=2, head_dim=12)
    base.update(kw)
    return A.AttnSpec(**base)


def _params(spec, seed=0):
    return A.make_attention(L.ParamMaker(jax.random.PRNGKey(seed),
                                         dtype=F32), "attn", spec)


def _x(b, s, d=48, seed=1):
    return jax.random.normal(jax.random.PRNGKey(seed), (b, s, d), F32)


def _pos(b, s):
    return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))


class TestMasks:
    def test_causal(self):
        qp = _pos(1, 4)
        bias = A._mask_bias(qp, qp, window=0, causal=True)[0]
        visible = (np.asarray(bias) == 0.0)
        want = np.tril(np.ones((4, 4), bool))
        np.testing.assert_array_equal(visible, want)

    def test_sliding_window(self):
        qp = _pos(1, 6)
        bias = A._mask_bias(qp, qp, window=3, causal=True)[0]
        visible = (np.asarray(bias) == 0.0)
        for i in range(6):
            for j in range(6):
                assert visible[i, j] == (j <= i and i - j < 3), (i, j)

    def test_empty_slots_masked(self):
        qp = jnp.array([[5]], jnp.int32)
        kpos = jnp.array([[3, -1, 5, 7]], jnp.int32)   # -1 empty, 7 future
        bias = A._mask_bias(qp, kpos, window=0, causal=True)[0, 0]
        np.testing.assert_array_equal(np.asarray(bias) == 0.0,
                                      [True, False, True, False])


class TestCausality:
    def test_future_tokens_do_not_affect_past(self):
        spec = _spec()
        p = _params(spec)
        x1 = _x(1, 8)
        x2 = x1.at[:, 6:].set(123.0)
        o1, _ = A.attention(p, x1, _pos(1, 8), spec)
        o2, _ = A.attention(p, x2, _pos(1, 8), spec)
        np.testing.assert_allclose(np.asarray(o1[:, :6]),
                                   np.asarray(o2[:, :6]), atol=1e-5)

    def test_window_limits_context(self):
        spec = _spec(window=2)
        p = _params(spec)
        x1 = _x(1, 8)
        x2 = x1.at[:, 0].set(55.0)      # outside the window of position 7
        o1, _ = A.attention(p, x1, _pos(1, 8), spec)
        o2, _ = A.attention(p, x2, _pos(1, 8), spec)
        np.testing.assert_allclose(np.asarray(o1[:, 7]), np.asarray(o2[:, 7]),
                                   atol=1e-5)
        assert not np.allclose(np.asarray(o1[:, 1]), np.asarray(o2[:, 1]))


class TestRollingCache:
    def test_decode_equals_full_context_window(self):
        """Rolling (window-slot) decode == full attention with window mask."""
        spec = _spec(window=4)
        p = _params(spec)
        s_total = 10
        x = _x(1, s_total)
        full, _ = A.attention(p, x, _pos(1, s_total), spec)
        cache = A.init_cache(spec, 1, max_len=s_total, dtype=F32)
        assert cache["k"].shape[1] == 4            # window slots only
        outs = []
        for t in range(s_total):
            o, cache = A.attention(p, x[:, t:t + 1],
                                   jnp.full((1, 1), t, jnp.int32), spec,
                                   cache=cache, cache_index=jnp.int32(t))
            outs.append(o)
        np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                                   np.asarray(full), atol=1e-4)

    def test_prefill_longer_than_window(self):
        spec = _spec(window=4)
        p = _params(spec)
        x = _x(1, 10)
        cache = A.init_cache(spec, 1, max_len=16, dtype=F32)
        _, cache = A.attention(p, x, _pos(1, 10), spec, cache=cache)
        # cache retains exactly the last `window` positions
        kept = sorted(int(v) for v in np.asarray(cache["pos"][0]))
        assert kept == [6, 7, 8, 9]
        # continuing decode matches full-context windowed attention
        x11 = _x(1, 11, seed=9)
        x_all = x11.at[:, :10].set(x)
        full, _ = A.attention(p, x_all, _pos(1, 11), spec)
        o, _ = A.attention(p, x_all[:, 10:11], jnp.full((1, 1), 10,
                                                        jnp.int32), spec,
                           cache=cache, cache_index=jnp.int32(10))
        np.testing.assert_allclose(np.asarray(o[:, 0]),
                                   np.asarray(full[:, 10]), atol=1e-4)


class TestMLA:
    def _mla_spec(self, q_lora=0):
        return _spec(num_heads=4, num_kv_heads=4,
                     mla=MLAConfig(kv_lora_rank=16, q_lora_rank=q_lora,
                                   qk_rope_dim=8, qk_nope_dim=12,
                                   v_head_dim=12))

    @pytest.mark.parametrize("q_lora", [0, 24])
    def test_absorbed_decode_equals_naive(self, q_lora):
        """The absorbed-weight decode path == the naive train path."""
        spec = self._mla_spec(q_lora)
        p = _params(spec)
        s = 9
        x = _x(1, s)
        full, _ = A.attention(p, x, _pos(1, s), spec)
        cache = A.init_cache(spec, 1, max_len=s, dtype=F32)
        _, cache = A.attention(p, x[:, :s - 1], _pos(1, s - 1), spec,
                               cache=cache)
        o, _ = A.attention(p, x[:, s - 1:], jnp.full((1, 1), s - 1,
                                                     jnp.int32), spec,
                           cache=cache, cache_index=jnp.int32(s - 1))
        np.testing.assert_allclose(np.asarray(o[:, 0]),
                                   np.asarray(full[:, -1]), atol=1e-4)

    def test_cache_is_compressed(self):
        spec = self._mla_spec()
        cache = A.init_cache(spec, 2, 32, F32)
        assert set(cache) == {"ckv", "kr", "pos"}
        assert cache["ckv"].shape == (2, 32, 16)     # rank, not heads*dim
        assert cache["kr"].shape == (2, 32, 8)


class TestCrossAttention:
    def test_no_causal_mask_and_shapes(self):
        spec = _spec(causal=False, use_rope=False)
        p = _params(spec)
        x = _x(2, 5)
        kv = _x(2, 7, seed=3)
        o, cache = A.attention(p, x, _pos(2, 5), spec, kv_source=kv)
        assert o.shape == (2, 5, 48) and cache is None
        # swapping kv rows changes all outputs (no causality over kv)
        kv2 = kv[:, ::-1]
        o2, _ = A.attention(p, x, _pos(2, 5), spec, kv_source=kv2)
        assert not np.allclose(np.asarray(o), np.asarray(o2))


class TestHeadPadding:
    def test_padded_equals_unpadded_reference(self):
        spec_r = _spec(num_heads=3, num_kv_heads=1)
        spec_p = dataclasses.replace(spec_r, head_pad=4)
        pr = _params(spec_r)
        pp = _params(spec_p, seed=5)
        hd = spec_r.head_dim
        pp = {**pp,
              "wq": {"w": jnp.zeros_like(pp["wq"]["w"]).at[:, :3 * hd].set(
                  pr["wq"]["w"])},
              "wk": pr["wk"], "wv": pr["wv"],
              "wo": {"w": jnp.zeros_like(pp["wo"]["w"]).at[:3 * hd, :].set(
                  pr["wo"]["w"])}}
        x = _x(2, 6)
        o_r, _ = A.attention(pr, x, _pos(2, 6), spec_r)
        o_p, _ = A.attention(pp, x, _pos(2, 6), spec_p)
        np.testing.assert_allclose(np.asarray(o_r), np.asarray(o_p),
                                   atol=1e-5)

    def test_padded_decode_matches_prefill(self):
        spec = _spec(num_heads=3, num_kv_heads=1, head_pad=4)
        p = _params(spec)
        x = _x(1, 6)
        full, _ = A.attention(p, x, _pos(1, 6), spec)
        cache = A.init_cache(spec, 1, 8, F32)
        _, cache = A.attention(p, x[:, :5], _pos(1, 5), spec, cache=cache)
        o, _ = A.attention(p, x[:, 5:6], jnp.full((1, 1), 5, jnp.int32),
                           spec, cache=cache, cache_index=jnp.int32(5))
        np.testing.assert_allclose(np.asarray(o[:, 0]),
                                   np.asarray(full[:, 5]), atol=1e-4)

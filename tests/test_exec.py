"""Execution-engine tests: scheduler optimality, plan cache, executor
parity with the pure-jnp oracle (paper §4/§6.3 — flexible dataflows),
and the jit-compiled serving hot path (ISSUE 2): compiled == eager
bitwise, zero retraces on warm calls, lazy trace materialization."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dataflow as df
from repro.core import perf_model as pm
from repro.core.types import Backend, Dataflow, PhotonicConfig
from repro.exec import (PlanCache, compiled_forward, execute_cnn,
                        plan_for_network, plan_layer, plan_summary,
                        plan_table, plan_vs_fixed, reference_forward,
                        schedule_cnn, trace_count)
from repro.exec.scheduler import choose_tile
from repro.kernels import ops
from repro.models import cnn
from repro.models.cnn import (CNN_ZOO, LayerGemm, LoweredLayer,
                              build_small_cnn)

HEANA = pm.AcceleratorConfig.equal_area("heana", Dataflow.OS, 1.0)
AMW = pm.AcceleratorConfig.equal_area("amw", Dataflow.WS, 1.0)


class TestScheduler:
    def test_picks_perf_model_argmin_per_layer(self):
        """The planned dataflow is exactly the gemm_cost argmin."""
        for layer in (LayerGemm("fat_k", 64, 4096, 64),     # fat contraction
                      LayerGemm("fat_c", 8192, 64, 64),     # fat rows
                      LayerGemm("fc", 1, 2048, 1000)):
            for acc in (HEANA, AMW):
                plan = plan_layer(layer, acc, cache=PlanCache())
                g = df.GemmShape(layer.c, layer.k, layer.d)
                want = min(
                    Dataflow,
                    key=lambda f: (pm.gemm_cost(
                        g, dataclasses.replace(acc, dataflow=f)).latency_s,
                        pm.gemm_cost(
                        g, dataclasses.replace(acc, dataflow=f)).energy.total,
                        list(Dataflow).index(f)))
                assert plan.dataflow == want, (layer.name, acc.backend)

    def test_amw_fc_layer_prefers_input_stationary(self):
        """Known shape: a C=1 GEMM on a thermo-optic backend holds the one
        input row; IS ties WS on latency and wins the energy tie-break
        (one DAC-held input row vs re-streaming inputs per column tile)."""
        plan = plan_layer(LayerGemm("fc", 1, 2048, 1000), AMW,
                          cache=PlanCache())
        assert plan.dataflow == Dataflow.IS
        assert plan.candidates["is"] <= plan.candidates["ws"]

    def test_amw_batched_conv_prefers_weight_stationary(self):
        """Fat-C conv on AMW amortizes the 4us thermo-optic weight hold."""
        plan = plan_layer(LayerGemm("conv", 12544, 147, 64), AMW, batch=256,
                          cache=PlanCache())
        assert plan.dataflow == Dataflow.WS

    @pytest.mark.parametrize("name", list(CNN_ZOO))
    @pytest.mark.parametrize("batch", [1, 256])
    def test_auto_plan_at_least_best_fixed(self, name, batch):
        """Acceptance: auto-scheduled FPS >= best single fixed dataflow."""
        layers = CNN_ZOO[name]()
        for acc in (HEANA, AMW):
            plan = schedule_cnn(layers, acc, batch, cache=PlanCache())
            best = max(pm.cnn_inference(
                layers, dataclasses.replace(acc, dataflow=f), batch).fps
                for f in Dataflow)
            assert plan.fps >= best * (1 - 1e-12), (name, acc.backend, batch)

    def test_planned_totals_match_perf_model(self):
        """CnnPlan.result is literally cnn_inference under the plan's flows."""
        layers = CNN_ZOO["shufflenet_v2"]()
        plan = schedule_cnn(layers, HEANA, 1, cache=PlanCache())
        want = pm.cnn_inference(layers, HEANA, 1,
                                dataflows=list(plan.dataflows))
        assert plan.fps == want.fps
        assert plan.latency_s == want.latency_s

    def test_tile_choice_lane_aligned_and_covering(self):
        for m, d, k in ((1, 10, 2048), (784, 128, 864), (12544, 64, 147)):
            t = choose_tile(m, d, k, dpe_size=83)
            assert t.block_m % 8 == 0 and t.block_d % 128 == 0
            assert t.grid_m * t.block_m >= m
            assert t.grid_d * t.block_d >= d
            assert t.pad_waste >= 0.0


class TestPlanCache:
    def test_repeated_shapes_hit_within_one_cnn(self):
        cache = PlanCache()
        plan = schedule_cnn(CNN_ZOO["resnet50"](), HEANA, 1, cache=cache)
        assert plan.cache_hits > 0          # bottleneck blocks repeat shapes
        assert plan.cache_hits + plan.cache_misses == len(plan.layers)

    def test_replan_is_all_hits_and_identical(self):
        cache = PlanCache()
        p1 = schedule_cnn(CNN_ZOO["googlenet"](), HEANA, 1, cache=cache)
        p2 = schedule_cnn(CNN_ZOO["googlenet"](), HEANA, 1, cache=cache)
        assert p2.cache_hits == len(p2.layers) and p2.cache_misses == 0
        assert p1.dataflows == p2.dataflows
        assert p1.fps == p2.fps

    def test_key_sensitive_to_shape_config_objective(self):
        cache = PlanCache()
        base = plan_layer(LayerGemm("l", 64, 256, 64), HEANA, cache=cache)
        other_shape = plan_layer(LayerGemm("l", 64, 256, 65), HEANA,
                                 cache=cache)
        other_acc = plan_layer(LayerGemm("l", 64, 256, 64), AMW, cache=cache)
        other_obj = plan_layer(LayerGemm("l", 64, 256, 64), HEANA,
                               objective="energy", cache=cache)
        keys = {base.cache_key, other_shape.cache_key, other_acc.cache_key,
                other_obj.cache_key}
        assert len(keys) == 4
        assert cache.stats()["hits"] == 0

    def test_name_does_not_enter_the_key(self):
        cache = PlanCache()
        a = plan_layer(LayerGemm("alpha", 64, 256, 64), HEANA, cache=cache)
        b = plan_layer(LayerGemm("beta", 64, 256, 64), HEANA, cache=cache)
        assert a.cache_key == b.cache_key
        assert b.cache_hit and not a.cache_hit
        assert b.name == "beta"             # name re-attached on hit

    def test_dump_load_roundtrip(self, tmp_path):
        cache = PlanCache()
        schedule_cnn(CNN_ZOO["mobilenet_v2"](), HEANA, 1, cache=cache)
        path = str(tmp_path / "plans.json")
        cache.dump(path)
        fresh = PlanCache()
        assert fresh.load(path) == len(cache)
        plan = schedule_cnn(CNN_ZOO["mobilenet_v2"](), HEANA, 1, cache=fresh)
        assert plan.cache_misses == 0


class TestExecutor:
    def _setup(self, noise=False, bits=6):
        key = jax.random.PRNGKey(0)
        params = build_small_cnn(key)
        x = jax.random.normal(jax.random.fold_in(key, 1), (3, 16, 16, 3))
        cfg = PhotonicConfig(backend=Backend.HEANA, bits=bits, dpe_size=83,
                             noise_enabled=noise)
        plan = plan_for_network(params, HEANA, batch=3, cache=PlanCache())
        return params, x, cfg, plan

    def test_pallas_execution_bit_exact_vs_oracle(self):
        """Acceptance: end-to-end Pallas inference == jnp reference exactly
        with noise disabled (bits=6 keeps every partial sum < 2^24)."""
        params, x, cfg, plan = self._setup()
        res = execute_cnn(params, x, plan, cfg, impl="pallas")
        ref = reference_forward(params, x, cfg)
        np.testing.assert_array_equal(np.asarray(res.logits),
                                      np.asarray(ref))

    def test_ref_impl_matches_models_own_forward(self):
        """Executor lowering is faithful to small_cnn_apply itself."""
        params, x, cfg, plan = self._setup()
        res = execute_cnn(params, x, plan, cfg, impl="ref")
        ref = reference_forward(params, x, cfg)
        np.testing.assert_array_equal(np.asarray(res.logits),
                                      np.asarray(ref))

    def test_noise_keys_reproducible_per_layer(self):
        params, x, cfg, plan = self._setup(noise=True)
        r1 = execute_cnn(params, x, plan, cfg, key=jax.random.PRNGKey(5))
        r2 = execute_cnn(params, x, plan, cfg, key=jax.random.PRNGKey(5))
        r3 = execute_cnn(params, x, plan, cfg, key=jax.random.PRNGKey(6))
        np.testing.assert_array_equal(np.asarray(r1.logits),
                                      np.asarray(r2.logits))
        assert not np.array_equal(np.asarray(r1.logits),
                                  np.asarray(r3.logits))

    def test_traces_carry_plan_and_numerics(self):
        params, x, cfg, plan = self._setup()
        res = execute_cnn(params, x, plan, cfg, impl="ref",
                          collect_activations=True)
        assert [t.name for t in res.traces] == ["conv1", "conv2", "conv3",
                                                "fc"]
        assert all(t.latency_s > 0 for t in res.traces)
        assert len(res.activations) == 4
        assert res.logits.shape == (3, 10)

    def test_plan_lowering_mismatch_raises(self):
        params, x, cfg, _ = self._setup()
        bad = schedule_cnn([LayerGemm("only", 256, 27, 16)], HEANA,
                           cache=PlanCache())
        with pytest.raises(ValueError, match="lowering"):
            execute_cnn(params, x, bad, cfg)

    def test_batch_mismatch_raises(self):
        params, x, cfg, plan = self._setup()       # plan at batch 3
        x8 = jnp.concatenate([x, x, x[:2]], axis=0)
        with pytest.raises(ValueError, match="batch"):
            execute_cnn(params, x8, plan, cfg)

    def test_lowered_gemms_rejects_wrong_in_hw(self):
        params = build_small_cnn(jax.random.PRNGKey(0), in_hw=32)
        with pytest.raises(ValueError, match="in_hw"):
            cnn.lowered_gemms(params)              # default in_hw=16
        gemms = cnn.lowered_gemms(params, in_hw=32)
        assert gemms[0].c == 32 * 32

    def test_lowered_gemms_match_forward_shapes(self):
        params = build_small_cnn(jax.random.PRNGKey(0))
        gemms = cnn.lowered_gemms(params)
        assert [(g.name, g.c, g.k, g.d) for g in gemms] == [
            ("conv1", 256, 27, 16), ("conv2", 64, 144, 32),
            ("conv3", 16, 288, 32), ("fc", 1, 512, 10)]


def _custom_lowering():
    """A runnable network that is NOT the small CNN: two convs (one 5x5),
    one pool, fc — exercises the lowering-driven oracle (ISSUE 2 satellite:
    reference_forward used to hardcode small_cnn_apply)."""
    return (
        LoweredLayer("ca", "conv", relu=True, pool_after=True, kk=3),
        LoweredLayer("cb", "conv", relu=True, pool_after=False, kk=5),
        LoweredLayer("out", "fc", relu=False, pool_after=False),
    )


def _custom_params(key, in_hw=8, in_ch=2):
    k1, k2, k3 = jax.random.split(key, 3)
    mk = lambda k, shape: jax.random.normal(k, shape, jnp.float32) \
        / jnp.sqrt(shape[0])
    return {
        "ca": mk(k1, (in_ch * 9, 8)),
        "cb": mk(k2, (8 * 25, 12)),
        "out": mk(k3, ((in_hw // 2) ** 2 * 12, 5)),
    }


class TestCompiledForward:
    """The serving hot path: jit-compiled forward == eager, no retraces."""

    def _setup(self, batch=3, noise=False, bits=6):
        key = jax.random.PRNGKey(0)
        params = build_small_cnn(key)
        x = jax.random.normal(jax.random.fold_in(key, 1),
                              (batch, 16, 16, 3))
        cfg = PhotonicConfig(backend=Backend.HEANA, bits=bits, dpe_size=83,
                             noise_enabled=noise)
        plan = plan_for_network(params, HEANA, batch=batch,
                                cache=PlanCache())
        return params, x, cfg, plan

    @pytest.mark.parametrize("noise", [False, True])
    @pytest.mark.parametrize("batch", [1, 3])
    def test_compiled_bit_exact_vs_eager_pallas(self, noise, batch):
        """Acceptance: the compiled forward is bit-exact vs the eager
        op-by-op path, noise on and off."""
        params, x, cfg, plan = self._setup(batch=batch, noise=noise)
        key = jax.random.PRNGKey(11) if noise else None
        c = execute_cnn(params, x, plan, cfg, key=key, impl="pallas")
        e = execute_cnn(params, x, plan, cfg, key=key, impl="pallas",
                        compiled=False)
        np.testing.assert_array_equal(np.asarray(c.logits),
                                      np.asarray(e.logits))
        # fingerprints are diagnostics: same program, but reduction order
        # may differ between fused/eager reduces — tight tolerance only
        # (per-GEMM-node fingerprints reduce over the pre-pool tensors,
        # so the fused/eager divergence is a few ULP larger than before)
        np.testing.assert_allclose(np.asarray(c.fingerprints),
                                   np.asarray(e.fingerprints), rtol=5e-6)

    @pytest.mark.parametrize("noise", [False, True])
    def test_compiled_bit_exact_vs_eager_batch256(self, noise):
        """Acceptance at the serving batch (256) — ref impl keeps the
        eager baseline affordable in CI (benchmarks/throughput.py covers
        the Pallas impl at 256); tilings come from the batch-256 plan."""
        params = build_small_cnn(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(2), (256, 16, 16, 3))
        cfg = PhotonicConfig(backend=Backend.HEANA, bits=6, dpe_size=83,
                             noise_enabled=noise)
        plan = plan_for_network(params, HEANA, batch=256,
                                cache=PlanCache())
        key = jax.random.PRNGKey(11) if noise else None
        c = execute_cnn(params, x, plan, cfg, key=key, impl="ref")
        e = execute_cnn(params, x, plan, cfg, key=key, impl="ref",
                        compiled=False)
        np.testing.assert_array_equal(np.asarray(c.logits),
                                      np.asarray(e.logits))

    def test_no_retrace_on_repeated_calls(self):
        """Acceptance: warm compiled calls never re-trace (the pre-fix
        executor re-traced every inference)."""
        params, x, cfg, plan = self._setup()
        execute_cnn(params, x, plan, cfg)           # cold: traces once
        before = trace_count()
        for _ in range(3):
            execute_cnn(params, x, plan, cfg)
        assert trace_count() == before
        # a replanned (equal) plan must hit the same executable
        plan2 = plan_for_network(params, HEANA, batch=3, cache=PlanCache())
        execute_cnn(params, x, plan2, cfg)
        assert trace_count() == before

    def test_new_batch_shape_traces_once(self):
        params, x, cfg, plan = self._setup()
        execute_cnn(params, x, plan, cfg)
        before = trace_count()
        plan8 = plan_for_network(params, HEANA, batch=8, cache=PlanCache())
        x8 = jax.random.normal(jax.random.PRNGKey(3), (8, 16, 16, 3))
        execute_cnn(params, x8, plan8, cfg)
        assert trace_count() == before + 1          # one new shape: 1 trace
        execute_cnn(params, x8, plan8, cfg)
        assert trace_count() == before + 1

    def test_eager_path_runs_python_body_every_call(self):
        params, x, cfg, plan = self._setup()
        before = trace_count()
        execute_cnn(params, x, plan, cfg, compiled=False)
        execute_cnn(params, x, plan, cfg, compiled=False)
        assert trace_count() == before + 2

    def test_compiled_forward_memo_shares_wrapper(self):
        """Equal planning problems (distinct plan objects) share one
        compiled wrapper (content-addressed memo)."""
        params, x, cfg, plan = self._setup()
        plan2 = plan_for_network(params, HEANA, batch=3, cache=PlanCache())
        assert plan is not plan2
        assert compiled_forward(plan, cfg) is compiled_forward(plan2, cfg)

    def test_compiled_forward_memo_is_bounded(self):
        """The wrapper memo is LRU-bounded (serving processes must not
        grow without limit)."""
        from repro.exec import executor as ex
        params, _, cfg, plan = self._setup()
        compiled_forward(plan, cfg)
        assert len(ex._FORWARD_CACHE) <= ex._FORWARD_CACHE_MAX

    def test_plans_are_hashable_and_value_equal(self):
        """CnnPlan/LayerPlan/TileChoice serve as static jit args."""
        params, _, _, plan = self._setup()
        plan2 = plan_for_network(params, HEANA, batch=3, cache=PlanCache())
        assert hash(plan) == hash(plan2) and plan == plan2
        assert hash(plan.layers[0]) == hash(plan2.layers[0])
        assert hash(plan.layers[0].tile) == hash(plan2.layers[0].tile)
        other = plan_for_network(params, HEANA, batch=4, cache=PlanCache())
        assert plan != other
        with pytest.raises(TypeError, match="immutable"):
            plan.layers[0].candidates["os"] = 0.0

    def test_traces_materialize_lazily(self):
        params, x, cfg, plan = self._setup()
        res = execute_cnn(params, x, plan, cfg)
        assert res._traces is None                  # nothing synced yet
        assert res.fingerprints.shape == (len(plan.layers),)
        traces = res.traces                         # first access builds
        assert res._traces is traces
        assert [t.name for t in traces] == ["conv1", "conv2", "conv3", "fc"]
        assert all(t.out_mean_abs > 0 for t in traces)

    def test_fc_trace_m_is_batch_rows_not_placeholder(self):
        """Satellite fix: fc layers used to trace m=-1."""
        params, x, cfg, plan = self._setup(batch=3)
        res = execute_cnn(params, x, plan, cfg)
        fc = res.traces[-1]
        assert fc.name == "fc" and fc.m == 3        # batch folded into M
        assert all(t.m > 0 for t in res.traces)


class TestOracleLowering:
    """reference_forward drives the SAME lowering the executor runs."""

    def _setup(self, noise=False):
        key = jax.random.PRNGKey(4)
        lowering = _custom_lowering()
        params = _custom_params(key)
        x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, 8, 2))
        cfg = PhotonicConfig(backend=Backend.HEANA, bits=6, dpe_size=83,
                             noise_enabled=noise)
        plan = plan_for_network(params, HEANA, batch=2, in_hw=8,
                                lowering=lowering, cache=PlanCache())
        return params, x, cfg, plan, lowering

    @pytest.mark.parametrize("impl", ["ref", "pallas"])
    def test_executor_matches_oracle_on_non_small_lowering(self, impl):
        params, x, cfg, plan, lowering = self._setup()
        res = execute_cnn(params, x, plan, cfg, impl=impl,
                          lowering=lowering)
        ref = reference_forward(params, x, cfg, lowering=lowering)
        np.testing.assert_array_equal(np.asarray(res.logits),
                                      np.asarray(ref))
        assert res.logits.shape == (2, 5)

    def test_oracle_differs_from_small_cnn_apply(self):
        """Guard against the old bug: the oracle is NOT the small CNN."""
        params, x, cfg, _, lowering = self._setup()
        ref = reference_forward(params, x, cfg, lowering=lowering)
        with pytest.raises(Exception):
            # driving these params through the small-CNN structure is a
            # shape error — exactly what the hardcoded oracle used to hide
            cnn.small_cnn_apply(params, x)
        assert ref.shape == (2, 5)


class TestRectangularInputs:
    """The executor used to assume H == W (hw = x.shape[1])."""

    def _setup(self, h=16, w=8):
        key = jax.random.PRNGKey(5)
        # small-CNN convs are spatial-size agnostic; swap in a
        # rect-compatible fc ((h//4)*(w//4)*32 inputs after two pools)
        params = dict(build_small_cnn(key))
        params["fc"] = jax.random.normal(jax.random.fold_in(key, 9),
                                         ((h // 4) * (w // 4) * 32, 10),
                                         jnp.float32)
        x = jax.random.normal(jax.random.fold_in(key, 1), (2, h, w, 3))
        cfg = PhotonicConfig(backend=Backend.HEANA, bits=6, dpe_size=83,
                             noise_enabled=False)
        return params, x, cfg

    def test_rectangular_input_matches_oracle(self):
        params, x, cfg = self._setup()
        plan = plan_for_network(params, HEANA, batch=2, in_hw=(16, 8),
                                cache=PlanCache())
        res = execute_cnn(params, x, plan, cfg, impl="ref")
        ref = reference_forward(params, x, cfg)
        np.testing.assert_array_equal(np.asarray(res.logits),
                                      np.asarray(ref))
        assert res.logits.shape == (2, 10)

    def test_square_plan_on_rect_input_raises_clearly(self):
        params, x, cfg = self._setup()
        square = plan_for_network(build_small_cnn(jax.random.PRNGKey(5)),
                                  HEANA, batch=2, cache=PlanCache())
        with pytest.raises(ValueError, match="rows"):
            execute_cnn(params, x, square, cfg)

    def test_odd_spatial_dim_pooling_raises(self):
        params, x, cfg = self._setup()
        with pytest.raises(ValueError, match="does not tile H=15"):
            cnn.lowered_gemms(params, in_hw=(15, 8))
        plan = plan_for_network(params, HEANA, batch=2, in_hw=(16, 8),
                                cache=PlanCache())
        x_odd = jax.random.normal(jax.random.PRNGKey(2), (2, 15, 8, 3))
        with pytest.raises(ValueError, match="does not tile|rows"):
            execute_cnn(params, x_odd, plan, cfg)

    def test_non_image_input_raises(self):
        params, x, cfg = self._setup()
        plan = plan_for_network(params, HEANA, batch=2, in_hw=(16, 8),
                                cache=PlanCache())
        with pytest.raises(ValueError, match="images"):
            execute_cnn(params, x.reshape(2, -1), plan, cfg)


class TestPlanCacheHardening:
    """Atomic dump, tolerant load, LRU bound (serving-deployment fixes)."""

    def test_corrupt_file_loads_zero_not_raises(self, tmp_path):
        path = tmp_path / "plans.json"
        path.write_text('{"truncated": ')           # crash-mid-write relic
        cache = PlanCache()
        with pytest.warns(RuntimeWarning, match="unreadable"):
            assert cache.load(str(path)) == 0
        assert len(cache) == 0
        # cache still fully usable afterwards
        plan = schedule_cnn(CNN_ZOO["shufflenet_v2"](), HEANA, 1,
                            cache=cache)
        assert plan.cache_misses > 0

    def test_malformed_entries_skipped_valid_merged(self, tmp_path):
        cache = PlanCache()
        schedule_cnn(CNN_ZOO["mobilenet_v2"](), HEANA, 1, cache=cache)
        path = str(tmp_path / "plans.json")
        cache.dump(path)
        blob = json.load(open(path))
        n_valid = len(blob)
        blob["bad-entry"] = {"not": "a plan"}
        blob["worse"] = 17
        json.dump(blob, open(path, "w"))
        fresh = PlanCache()
        with pytest.warns(RuntimeWarning, match="malformed"):
            assert fresh.load(path) == n_valid
        plan = schedule_cnn(CNN_ZOO["mobilenet_v2"](), HEANA, 1,
                            cache=fresh)
        assert plan.cache_misses == 0

    def test_dump_replaces_atomically_no_temp_left(self, tmp_path):
        cache = PlanCache()
        schedule_cnn(CNN_ZOO["shufflenet_v2"](), HEANA, 1, cache=cache)
        path = tmp_path / "plans.json"
        path.write_text('{"stale": true}')
        cache.dump(str(path))
        assert json.load(open(path)) != {"stale": True}
        leftovers = [p for p in tmp_path.iterdir() if p.name != path.name]
        assert leftovers == []

    def test_non_dict_json_loads_zero(self, tmp_path):
        path = tmp_path / "plans.json"
        path.write_text('[1, 2, 3]')
        with pytest.warns(RuntimeWarning, match="not a JSON object"):
            assert PlanCache().load(str(path)) == 0

    def test_lru_bound_evicts_oldest(self):
        cache = PlanCache(max_entries=2)
        a = plan_layer(LayerGemm("a", 64, 256, 64), HEANA, cache=cache)
        plan_layer(LayerGemm("b", 64, 256, 65), HEANA, cache=cache)
        # touch a so b is the LRU entry
        assert cache.get(a.cache_key) is not None
        plan_layer(LayerGemm("c", 64, 256, 66), HEANA, cache=cache)
        assert len(cache) == 2
        assert cache.stats()["evictions"] == 1
        assert cache.get(a.cache_key) is not None   # survived (recently used)
        re_b = plan_layer(LayerGemm("b", 64, 256, 65), HEANA, cache=cache)
        assert not re_b.cache_hit                   # b was evicted

    def test_max_entries_validation(self):
        with pytest.raises(ValueError, match="max_entries"):
            PlanCache(max_entries=0)

    def test_load_never_overstates_retained(self, tmp_path):
        """A dump larger than max_entries merges a truncated tail and
        returns what actually survived, with a warning."""
        big = PlanCache()
        schedule_cnn(CNN_ZOO["mobilenet_v2"](), HEANA, 1, cache=big)
        assert len(big) > 2
        path = str(tmp_path / "plans.json")
        big.dump(path)
        small = PlanCache(max_entries=2)
        with pytest.warns(RuntimeWarning, match="merging only"):
            loaded = small.load(path)
        assert loaded == 2 == len(small)

    def test_dump_preserves_lru_order_through_overflow(self, tmp_path):
        """ISSUE 4 satellite: dump used sort_keys on the store, so an
        overflowing load trimmed a LEXICOGRAPHIC subset of the sha256
        keys instead of the most-recently-used entries it promises.
        Round trip: touch a known subset, dump, load into a smaller
        cache — exactly the MRU entries must survive."""
        big = PlanCache()
        plans = [plan_layer(LayerGemm(f"l{i}", 64, 256, 64 + i), HEANA,
                            cache=big) for i in range(8)]
        # Touch 3 entries (spread across the key space) to make them MRU.
        mru = [plans[i].cache_key for i in (5, 0, 3)]
        for k in mru:
            assert big.get(k) is not None
        path = str(tmp_path / "plans.json")
        big.dump(path)
        small = PlanCache(max_entries=3)
        with pytest.warns(RuntimeWarning, match="merging only"):
            assert small.load(path) == 3
        for k in mru:                    # the touched (MRU) set survived
            assert small.get(k) is not None
        lru_keys = {p.cache_key for p in plans} - set(mru)
        for k in lru_keys:
            assert small.get(k) is None

    def test_degenerate_adc_full_scale_does_not_crash(self):
        """adc_round keeps adc_readout's floor: fs=0 clamps, no div-zero."""
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 32))
        w = jax.random.normal(jax.random.PRNGKey(1), (32, 8))
        cfg = PhotonicConfig(backend=Backend.HEANA, bits=6, dpe_size=83,
                             noise_enabled=False)
        out = ops.photonic_matmul(x, w, cfg, impl="ref", adc_fs=0.0)
        assert bool(jnp.all(jnp.isfinite(out)))


class TestNoiseKeyValidation:
    """noise_enabled=True + key=None must fail loudly, not run silent."""

    def _cfg(self, noise=True):
        return PhotonicConfig(backend=Backend.HEANA, bits=6, dpe_size=83,
                              noise_enabled=noise)

    def test_photonic_matmul_raises_without_key(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 32))
        w = jax.random.normal(jax.random.PRNGKey(1), (32, 8))
        with pytest.raises(ValueError, match="noise_enabled"):
            ops.photonic_matmul(x, w, self._cfg())

    def test_photonic_matmul_ok_with_key_or_noise_off(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 32))
        w = jax.random.normal(jax.random.PRNGKey(1), (32, 8))
        noisy = ops.photonic_matmul(x, w, self._cfg(),
                                    key=jax.random.PRNGKey(2), impl="ref")
        clean = ops.photonic_matmul(x, w, self._cfg(noise=False),
                                    impl="ref")
        assert noisy.shape == clean.shape == (4, 8)
        assert not np.array_equal(np.asarray(noisy), np.asarray(clean))

    def test_execute_cnn_raises_without_key(self):
        params = build_small_cnn(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 3))
        plan = plan_for_network(params, HEANA, batch=2, cache=PlanCache())
        with pytest.raises(ValueError, match="noise_enabled"):
            execute_cnn(params, x, plan, self._cfg())

    def test_reference_forward_rejects_noisy_cfg(self):
        """The oracle is deterministic by definition — a noise-enabled cfg
        without a key can't silently run noiseless anymore."""
        params = build_small_cnn(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 3))
        with pytest.raises(ValueError, match="noise_enabled"):
            reference_forward(params, x, self._cfg())


class TestReport:
    def test_summary_and_table_render(self):
        plan = schedule_cnn(CNN_ZOO["googlenet"](), HEANA, 1,
                            cache=PlanCache())
        s = plan_summary(plan, "googlenet")
        assert s["n_layers"] == len(plan.layers)
        assert sum(s["dataflow_mix"].values()) == len(plan.layers)
        assert abs(s["fps"] - plan.fps) < 1e-9
        table = plan_table(plan, max_rows=3)
        assert table.count("\n") >= 4
        fixed = {f: pm.cnn_inference(
            CNN_ZOO["googlenet"](), dataclasses.replace(HEANA, dataflow=f)
            ).fps for f in Dataflow}
        cmp = plan_vs_fixed(plan, fixed)
        assert cmp["uplift"] >= 1.0 - 1e-12

"""Execution-engine tests: scheduler optimality, plan cache, executor
parity with the pure-jnp oracle (paper §4/§6.3 — flexible dataflows)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dataflow as df
from repro.core import perf_model as pm
from repro.core.types import Backend, Dataflow, PhotonicConfig
from repro.exec import (PlanCache, execute_cnn, plan_for_network, plan_layer,
                        plan_summary, plan_table, plan_vs_fixed,
                        reference_forward, schedule_cnn)
from repro.exec.scheduler import choose_tile
from repro.models import cnn
from repro.models.cnn import CNN_ZOO, LayerGemm, build_small_cnn

HEANA = pm.AcceleratorConfig.equal_area("heana", Dataflow.OS, 1.0)
AMW = pm.AcceleratorConfig.equal_area("amw", Dataflow.WS, 1.0)


class TestScheduler:
    def test_picks_perf_model_argmin_per_layer(self):
        """The planned dataflow is exactly the gemm_cost argmin."""
        for layer in (LayerGemm("fat_k", 64, 4096, 64),     # fat contraction
                      LayerGemm("fat_c", 8192, 64, 64),     # fat rows
                      LayerGemm("fc", 1, 2048, 1000)):
            for acc in (HEANA, AMW):
                plan = plan_layer(layer, acc, cache=PlanCache())
                g = df.GemmShape(layer.c, layer.k, layer.d)
                want = min(
                    Dataflow,
                    key=lambda f: (pm.gemm_cost(
                        g, dataclasses.replace(acc, dataflow=f)).latency_s,
                        pm.gemm_cost(
                        g, dataclasses.replace(acc, dataflow=f)).energy.total,
                        list(Dataflow).index(f)))
                assert plan.dataflow == want, (layer.name, acc.backend)

    def test_amw_fc_layer_prefers_input_stationary(self):
        """Known shape: a C=1 GEMM on a thermo-optic backend holds the one
        input row; IS ties WS on latency and wins the energy tie-break
        (one DAC-held input row vs re-streaming inputs per column tile)."""
        plan = plan_layer(LayerGemm("fc", 1, 2048, 1000), AMW,
                          cache=PlanCache())
        assert plan.dataflow == Dataflow.IS
        assert plan.candidates["is"] <= plan.candidates["ws"]

    def test_amw_batched_conv_prefers_weight_stationary(self):
        """Fat-C conv on AMW amortizes the 4us thermo-optic weight hold."""
        plan = plan_layer(LayerGemm("conv", 12544, 147, 64), AMW, batch=256,
                          cache=PlanCache())
        assert plan.dataflow == Dataflow.WS

    @pytest.mark.parametrize("name", list(CNN_ZOO))
    @pytest.mark.parametrize("batch", [1, 256])
    def test_auto_plan_at_least_best_fixed(self, name, batch):
        """Acceptance: auto-scheduled FPS >= best single fixed dataflow."""
        layers = CNN_ZOO[name]()
        for acc in (HEANA, AMW):
            plan = schedule_cnn(layers, acc, batch, cache=PlanCache())
            best = max(pm.cnn_inference(
                layers, dataclasses.replace(acc, dataflow=f), batch).fps
                for f in Dataflow)
            assert plan.fps >= best * (1 - 1e-12), (name, acc.backend, batch)

    def test_planned_totals_match_perf_model(self):
        """CnnPlan.result is literally cnn_inference under the plan's flows."""
        layers = CNN_ZOO["shufflenet_v2"]()
        plan = schedule_cnn(layers, HEANA, 1, cache=PlanCache())
        want = pm.cnn_inference(layers, HEANA, 1,
                                dataflows=list(plan.dataflows))
        assert plan.fps == want.fps
        assert plan.latency_s == want.latency_s

    def test_tile_choice_lane_aligned_and_covering(self):
        for m, d, k in ((1, 10, 2048), (784, 128, 864), (12544, 64, 147)):
            t = choose_tile(m, d, k, dpe_size=83)
            assert t.block_m % 8 == 0 and t.block_d % 128 == 0
            assert t.grid_m * t.block_m >= m
            assert t.grid_d * t.block_d >= d
            assert t.pad_waste >= 0.0


class TestPlanCache:
    def test_repeated_shapes_hit_within_one_cnn(self):
        cache = PlanCache()
        plan = schedule_cnn(CNN_ZOO["resnet50"](), HEANA, 1, cache=cache)
        assert plan.cache_hits > 0          # bottleneck blocks repeat shapes
        assert plan.cache_hits + plan.cache_misses == len(plan.layers)

    def test_replan_is_all_hits_and_identical(self):
        cache = PlanCache()
        p1 = schedule_cnn(CNN_ZOO["googlenet"](), HEANA, 1, cache=cache)
        p2 = schedule_cnn(CNN_ZOO["googlenet"](), HEANA, 1, cache=cache)
        assert p2.cache_hits == len(p2.layers) and p2.cache_misses == 0
        assert p1.dataflows == p2.dataflows
        assert p1.fps == p2.fps

    def test_key_sensitive_to_shape_config_objective(self):
        cache = PlanCache()
        base = plan_layer(LayerGemm("l", 64, 256, 64), HEANA, cache=cache)
        other_shape = plan_layer(LayerGemm("l", 64, 256, 65), HEANA,
                                 cache=cache)
        other_acc = plan_layer(LayerGemm("l", 64, 256, 64), AMW, cache=cache)
        other_obj = plan_layer(LayerGemm("l", 64, 256, 64), HEANA,
                               objective="energy", cache=cache)
        keys = {base.cache_key, other_shape.cache_key, other_acc.cache_key,
                other_obj.cache_key}
        assert len(keys) == 4
        assert cache.stats()["hits"] == 0

    def test_name_does_not_enter_the_key(self):
        cache = PlanCache()
        a = plan_layer(LayerGemm("alpha", 64, 256, 64), HEANA, cache=cache)
        b = plan_layer(LayerGemm("beta", 64, 256, 64), HEANA, cache=cache)
        assert a.cache_key == b.cache_key
        assert b.cache_hit and not a.cache_hit
        assert b.name == "beta"             # name re-attached on hit

    def test_dump_load_roundtrip(self, tmp_path):
        cache = PlanCache()
        schedule_cnn(CNN_ZOO["mobilenet_v2"](), HEANA, 1, cache=cache)
        path = str(tmp_path / "plans.json")
        cache.dump(path)
        fresh = PlanCache()
        assert fresh.load(path) == len(cache)
        plan = schedule_cnn(CNN_ZOO["mobilenet_v2"](), HEANA, 1, cache=fresh)
        assert plan.cache_misses == 0


class TestExecutor:
    def _setup(self, noise=False, bits=6):
        key = jax.random.PRNGKey(0)
        params = build_small_cnn(key)
        x = jax.random.normal(jax.random.fold_in(key, 1), (3, 16, 16, 3))
        cfg = PhotonicConfig(backend=Backend.HEANA, bits=bits, dpe_size=83,
                             noise_enabled=noise)
        plan = plan_for_network(params, HEANA, batch=3, cache=PlanCache())
        return params, x, cfg, plan

    def test_pallas_execution_bit_exact_vs_oracle(self):
        """Acceptance: end-to-end Pallas inference == jnp reference exactly
        with noise disabled (bits=6 keeps every partial sum < 2^24)."""
        params, x, cfg, plan = self._setup()
        res = execute_cnn(params, x, plan, cfg, impl="pallas")
        ref = reference_forward(params, x, cfg)
        np.testing.assert_array_equal(np.asarray(res.logits),
                                      np.asarray(ref))

    def test_ref_impl_matches_models_own_forward(self):
        """Executor lowering is faithful to small_cnn_apply itself."""
        params, x, cfg, plan = self._setup()
        res = execute_cnn(params, x, plan, cfg, impl="ref")
        ref = reference_forward(params, x, cfg)
        np.testing.assert_array_equal(np.asarray(res.logits),
                                      np.asarray(ref))

    def test_noise_keys_reproducible_per_layer(self):
        params, x, cfg, plan = self._setup(noise=True)
        r1 = execute_cnn(params, x, plan, cfg, key=jax.random.PRNGKey(5))
        r2 = execute_cnn(params, x, plan, cfg, key=jax.random.PRNGKey(5))
        r3 = execute_cnn(params, x, plan, cfg, key=jax.random.PRNGKey(6))
        np.testing.assert_array_equal(np.asarray(r1.logits),
                                      np.asarray(r2.logits))
        assert not np.array_equal(np.asarray(r1.logits),
                                  np.asarray(r3.logits))

    def test_traces_carry_plan_and_numerics(self):
        params, x, cfg, plan = self._setup()
        res = execute_cnn(params, x, plan, cfg, impl="ref",
                          collect_activations=True)
        assert [t.name for t in res.traces] == ["conv1", "conv2", "conv3",
                                                "fc"]
        assert all(t.latency_s > 0 for t in res.traces)
        assert len(res.activations) == 4
        assert res.logits.shape == (3, 10)

    def test_plan_lowering_mismatch_raises(self):
        params, x, cfg, _ = self._setup()
        bad = schedule_cnn([LayerGemm("only", 256, 27, 16)], HEANA,
                           cache=PlanCache())
        with pytest.raises(ValueError, match="lowering"):
            execute_cnn(params, x, bad, cfg)

    def test_batch_mismatch_raises(self):
        params, x, cfg, plan = self._setup()       # plan at batch 3
        x8 = jnp.concatenate([x, x, x[:2]], axis=0)
        with pytest.raises(ValueError, match="batch"):
            execute_cnn(params, x8, plan, cfg)

    def test_lowered_gemms_rejects_wrong_in_hw(self):
        params = build_small_cnn(jax.random.PRNGKey(0), in_hw=32)
        with pytest.raises(ValueError, match="in_hw"):
            cnn.lowered_gemms(params)              # default in_hw=16
        gemms = cnn.lowered_gemms(params, in_hw=32)
        assert gemms[0].c == 32 * 32

    def test_lowered_gemms_match_forward_shapes(self):
        params = build_small_cnn(jax.random.PRNGKey(0))
        gemms = cnn.lowered_gemms(params)
        assert [(g.name, g.c, g.k, g.d) for g in gemms] == [
            ("conv1", 256, 27, 16), ("conv2", 64, 144, 32),
            ("conv3", 16, 288, 32), ("fc", 1, 512, 10)]


class TestReport:
    def test_summary_and_table_render(self):
        plan = schedule_cnn(CNN_ZOO["googlenet"](), HEANA, 1,
                            cache=PlanCache())
        s = plan_summary(plan, "googlenet")
        assert s["n_layers"] == len(plan.layers)
        assert sum(s["dataflow_mix"].values()) == len(plan.layers)
        assert abs(s["fps"] - plan.fps) < 1e-9
        table = plan_table(plan, max_rows=3)
        assert table.count("\n") >= 4
        fixed = {f: pm.cnn_inference(
            CNN_ZOO["googlenet"](), dataclasses.replace(HEANA, dataflow=f)
            ).fps for f in Dataflow}
        cmp = plan_vs_fixed(plan, fixed)
        assert cmp["uplift"] >= 1.0 - 1e-12

"""Distribution-layer tests on a small in-process device mesh.

conftest note: these tests spawn with XLA_FLAGS forcing 8 host devices via
a subprocess-free trick — jax device count is locked at first use, so this
module must NOT run in the same process as tests that already initialized
jax with 1 device.  We therefore only test logic that doesn't need devices
(spec mapping, plans) here, plus mesh-dependent paths guarded by the
actual device count.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.models import model_zoo as zoo
from repro.parallel import sharding as shd


def _abstract_mesh():
    """16x16 (data, model) AbstractMesh across jax signature versions."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh((("data", 16), ("model", 16)))
    except TypeError:                      # older (shape, names) signature
        return AbstractMesh((16, 16), ("data", "model"))


class TestSpecMapping:
    def test_duplicate_mesh_axis_dropped(self):
        # MoE expert tensors: (EXPERT, EMBED, MLP) — expert FSDPs over
        # (model, data); mlp's 'model' is then already taken -> None
        ps = shd.spec_to_pspec(("expert", "embed", "mlp"))
        assert tuple(ps) == (("model", "data"), None, None)
        # without the FSDP rule, plain TP mapping
        ps2 = shd.spec_to_pspec(("expert", "embed", "mlp"),
                                {**shd.RULES, "expert": "model"})
        assert tuple(ps2) == ("model", None, None)

    def test_standard_mappings(self):
        assert tuple(shd.spec_to_pspec(("embed", "mlp"))) == (None, "model")
        assert tuple(shd.spec_to_pspec(("vocab", "embed"))) == \
            ("model", None)
        assert tuple(shd.spec_to_pspec(("stack", "embed", "heads"))) == \
            (None, None, "model")

    def test_param_specs_cover_every_leaf(self):
        for arch in ("qwen2-0.5b", "deepseek-v2-236b", "zamba2-7b",
                     "whisper-tiny"):
            cfg = get_config(arch, smoke=True)
            params = zoo.init_params(cfg, jax.random.PRNGKey(0),
                                     abstract=True)
            specs = zoo.param_specs(cfg)
            p_leaves = jax.tree.leaves(params)
            s_leaves = jax.tree.leaves(
                specs, is_leaf=lambda x: isinstance(x, tuple))
            assert len(p_leaves) == len(s_leaves)
            for p, s in zip(p_leaves, s_leaves):
                assert len(s) == p.ndim, (s, p.shape)

    def test_head_padding_in_param_shapes(self):
        cfg = get_config("qwen2-0.5b")           # 14 heads, head_pad=16
        params = zoo.init_params(cfg, jax.random.PRNGKey(0), abstract=True)
        group = params["body"]["stack"]
        assert group["attn"]["wq"]["w"].shape == \
            (24, cfg.d_model, 16 * cfg.resolved_head_dim)
        assert group["attn"]["wk"]["w"].shape == \
            (24, cfg.d_model, 2 * cfg.resolved_head_dim)   # kv NOT padded

    def test_divisible_fixup_replicates_odd_vocab(self):
        # whisper vocab 51865 isn't divisible by 16 -> replicated
        mesh = _abstract_mesh()
        cfg = get_config("whisper-tiny")
        abs_p = zoo.init_params(cfg, jax.random.PRNGKey(0), abstract=True)
        specs = zoo.param_specs(cfg)
        sh = shd.param_shardings(specs, mesh, abs_p)
        # table (51865, 384): vocab would map to model; fixup drops it
        emb = sh["embed"]["table"]
        assert tuple(emb.spec) in ((), (None,), (None, None))
        # qwen2 (151936 % 16 == 0) keeps the vocab sharding
        cfg2 = get_config("qwen2-0.5b")
        sh2 = shd.param_shardings(
            zoo.param_specs(cfg2), mesh,
            zoo.init_params(cfg2, jax.random.PRNGKey(0), abstract=True))
        assert sh2["embed"]["table"].spec[0] == "model"


class TestCacheShardings:
    def _mesh(self):
        return _abstract_mesh()

    def test_attention_cache_seq_sharded(self):
        mesh = self._mesh()
        cache = {"k": jax.ShapeDtypeStruct((128, 32768, 2, 128),
                                           jnp.bfloat16),
                 "pos": jax.ShapeDtypeStruct((128, 32768), jnp.int32)}
        sh = shd.cache_shardings(cache, mesh, 128)
        assert sh["k"].spec[1] == "model"        # flash-decode layout
        assert sh["pos"].spec[1] == "model"

    def test_ssm_state_heads_sharded(self):
        mesh = self._mesh()
        cache = {"ssm": jax.ShapeDtypeStruct((128, 112, 64, 64),
                                             jnp.float32)}
        sh = shd.cache_shardings(cache, mesh, 128)
        assert sh["ssm"].spec[1] == "model"

    def test_long_context_batch1_seq_data_sharded(self):
        mesh = self._mesh()
        cache = {"k": jax.ShapeDtypeStruct((1, 524288, 8, 240),
                                           jnp.bfloat16)}
        sh = shd.cache_shardings(cache, mesh, 1)
        spec = sh["k"].spec
        assert spec[0] is None                    # batch 1: not sharded
        assert spec[1] is not None                # sequence carries data/SP


class TestCellSupport:
    def test_supported_counts(self):
        from repro.configs import cell_is_supported, list_archs
        total = ok = 0
        for a in list_archs():
            for s in SHAPES.values():
                total += 1
                ok += cell_is_supported(get_config(a), s)[0]
        assert total == 40 and ok == 34           # 6 documented skips


class TestMoELoadBalance:
    def test_balanced_vs_collapsed_router(self):
        from repro.configs.base import MoEConfig
        from repro.models import moe as M

        e, d, t = 8, 16, 256
        cfg = MoEConfig(num_experts=e, experts_per_token=2, d_ff_expert=8)
        # positive activations so the "collapsed" router (one hot column)
        # deterministically wins the argmax
        x = jnp.abs(jax.random.normal(jax.random.PRNGKey(0), (1, t, d)))
        balanced = {"router": jnp.zeros((d, e), jnp.float32) +
                    0.01 * jax.random.normal(jax.random.PRNGKey(1), (d, e))}
        collapsed = {"router": jnp.zeros((d, e), jnp.float32)
                     .at[:, 0].set(10.0)}
        lb = float(M.load_balance_loss(balanced, x, cfg))
        lc = float(M.load_balance_loss(collapsed, x, cfg))
        assert lb < 2.0          # near-uniform routing -> loss ~ 1
        assert lc > e * 0.9      # total collapse -> loss ~ E

    def test_moe_capacity_drops_are_bounded(self):
        """With a generous capacity factor no tokens should drop: routed
        output must be nonzero for every token."""
        from repro.configs.base import MoEConfig
        from repro.models import layers as L
        from repro.models import moe as M

        cfg = MoEConfig(num_experts=4, experts_per_token=2, d_ff_expert=16,
                        capacity_factor=4.0)
        mk = L.ParamMaker(jax.random.PRNGKey(0), dtype=jnp.float32)
        params = M.make_moe(mk, "moe", 16, cfg)
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, 16))
        out = M.moe_ffn(params, x, cfg)
        norms = jnp.linalg.norm(out.reshape(-1, 16), axis=-1)
        assert float(jnp.min(norms)) > 0.0
